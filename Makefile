# Repo tooling: `make test` is the tier-1 gate (ROADMAP.md); bench
# targets accrue benchmark numbers per-PR.

PYTHONPATH := src
export PYTHONPATH

.PHONY: test test-fast test-shard test-fleet bench-serve analyze lint

test:
	python -m pytest -x -q

# fast lane: everything not marked `slow` (includes the packed
# MoE / Mix'n'Match / extra-precision serving regressions in
# tests/test_packed_moe_mnm.py and tests/test_packed_ep.py)
test-fast:
	python -m pytest -x -q -m "not slow"

# TP-sharded packed serving on a forced 8-device CPU host mesh; the
# device count must be pinned before jax is imported, so these tests
# skip under the plain `make test` run and get their own invocation
test-shard:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" JAX_PLATFORMS=cpu \
	    python -m pytest -x -q tests/test_serve_tp_packed.py \
	    tests/test_specdecode.py::test_spec_decode_token_exact_on_mesh

# replica-fleet serving on a forced 8-device CPU host, so
# make_replica_meshes hands each replica a real disjoint device
# subset (the module also runs single-device under plain `make test`)
test-fleet:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" JAX_PLATFORMS=cpu \
	    python -m pytest -x -q tests/test_fleet.py

bench-serve:
	python benchmarks/serve_throughput.py --reduced --out BENCH_serve.json

# matlint: the serving-contract static analyzer (docs/contracts.md;
# exit 0 clean / 1 findings / 2 analysis error). Pure stdlib -- needs
# no jax, so it runs anywhere, incl. its own CI lane.
analyze:
	python -m tools.analysis

lint: analyze
	python -m compileall -q src tests benchmarks examples tools
	@python -c "import pyflakes" 2>/dev/null \
	    && python -m pyflakes src/repro tests benchmarks examples tools \
	    || echo "pyflakes not installed; ran syntax check only"
	python tools/check_docs.py
