# Repo tooling: `make test` is the tier-1 gate (ROADMAP.md); bench
# targets accrue benchmark numbers per-PR.

PYTHONPATH := src
export PYTHONPATH

.PHONY: test test-fast bench-serve lint

test:
	python -m pytest -x -q

# fast lane: everything not marked `slow` (includes the packed
# MoE / Mix'n'Match / extra-precision serving regressions in
# tests/test_packed_moe_mnm.py and tests/test_packed_ep.py)
test-fast:
	python -m pytest -x -q -m "not slow"

bench-serve:
	python benchmarks/serve_throughput.py --reduced --out BENCH_serve.json

lint:
	python -m compileall -q src tests benchmarks examples tools
	@python -c "import pyflakes" 2>/dev/null \
	    && python -m pyflakes src/repro tests benchmarks examples tools \
	    || echo "pyflakes not installed; ran syntax check only"
	python tools/check_docs.py
