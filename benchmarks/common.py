"""Shared benchmark harness: tiny-model reproductions of paper tables.

Every table benchmark trains/calibrates small same-family models on the
synthetic Zipf-Markov corpus and reports log-pplx (the paper's quality
metric; absolute Gemma/Mistral numbers need the original checkpoints +
C4 -- DESIGN.md §5). Trained variants are cached on disk keyed by their
QuantConfig so the full suite re-runs quickly.

CSV contract (benchmarks.run): name,us_per_call,derived
  us_per_call -- wall time of one jitted eval forward
  derived     -- log pplx (NLL) of the row's served precision
"""

from __future__ import annotations

import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.matquant import cross_entropy
from repro.core.quant import QuantConfig
from repro.data import DataConfig, SyntheticCorpus
from repro.models import api
from repro.optim import OptConfig
from repro.train import init_train_state, make_train_step, omniquant_calib

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "bench_cache")
ARCH = "gemma2_2b"          # paper family; reduced() for CPU
PRETRAIN_STEPS = 250
QAT_STEPS = 120
BATCH, SEQ = 8, 64
DATA_SEED, EVAL_SEED = 11, 999


def tiny_cfg(qcfg: QuantConfig | None = None):
    cfg = get_config(ARCH).reduced().replace(num_layers=2)
    if qcfg is not None:
        cfg = cfg.replace(quant=qcfg)
    return cfg


def _corpus(cfg):
    return SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=SEQ, seed=DATA_SEED))


def _key_of(tag: str, qcfg: QuantConfig) -> str:
    blob = json.dumps([tag, qcfg.bitwidths, qcfg.parent_bits, qcfg.mode,
                       qcfg.scope, qcfg.extra_precision, qcfg.weights,
                       qcfg.codistill], default=str)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def _cache_load(key: str, like):
    from repro.runtime import checkpoint as ck
    path = os.path.join(CACHE_DIR, key)
    step = ck.latest_step(path)
    if step is None:
        return None
    try:
        return ck.restore(path, step, like)
    except Exception:
        return None


def _cache_save(key: str, tree):
    from repro.runtime import checkpoint as ck
    os.makedirs(CACHE_DIR, exist_ok=True)
    ck.save(os.path.join(CACHE_DIR, key), 0, tree)


def train_qat(qcfg: QuantConfig, steps: int = QAT_STEPS, *, from_pretrained=True,
              tag: str = "qat", lr: float = 5e-3, seed: int = 0):
    """Train (or load cached) a tiny model with the given quant config."""
    cfg = tiny_cfg(qcfg)
    key = _key_of(f"{tag}-{steps}-{from_pretrained}-{lr}-{seed}", qcfg)
    opt = OptConfig(lr=lr, total_steps=steps, warmup_steps=5)
    params, opt_state = init_train_state(jax.random.PRNGKey(seed), cfg, opt)
    cached = _cache_load(key, params)
    if cached is not None:
        return cached, cfg
    if from_pretrained:
        params = pretrained_base()[0]
    step = jax.jit(make_train_step(cfg, opt))
    corpus = _corpus(cfg)
    for i in range(steps):
        b = corpus.batch(i, BATCH, SEQ)
        params, opt_state, _ = step(params, opt_state,
                                    {k: jnp.asarray(v) for k, v in b.items()})
    _cache_save(key, params)
    return params, cfg


def pretrained_base():
    """One fp32 base model all methods start from (paper: a trained LLM)."""
    qcfg = QuantConfig(mode="bf16")
    cfg = tiny_cfg(qcfg)
    key = _key_of(f"pretrain-{PRETRAIN_STEPS}", qcfg)
    opt = OptConfig(lr=1e-2, total_steps=PRETRAIN_STEPS, warmup_steps=10)
    params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    cached = _cache_load(key, params)
    if cached is not None:
        return cached, cfg
    step = jax.jit(make_train_step(cfg, opt))
    corpus = _corpus(cfg)
    for i in range(PRETRAIN_STEPS):
        b = corpus.batch(i, BATCH, SEQ)
        params, opt_state, _ = step(params, opt_state,
                                    {k: jnp.asarray(v) for k, v in b.items()})
    _cache_save(key, params)
    return params, cfg


def calibrate_omniquant(qcfg: QuantConfig, steps_per_layer: int = 60):
    """OmniQuant-calibrate the pretrained base under the given config."""
    assert qcfg.mode == "omniquant"
    cfg = tiny_cfg(qcfg)
    base, _ = pretrained_base()
    params = api.init(jax.random.PRNGKey(0), cfg)  # structure w/ aux
    # copy base weights into the omniquant-structured params
    params = _merge_weights(params, base)
    key = _key_of(f"omni-{steps_per_layer}", qcfg)
    cached = _cache_load(key, params)
    if cached is not None:
        return cached, cfg
    corpus = _corpus(cfg)
    calib = jnp.asarray(corpus.batch(90_000, 8, SEQ)["tokens"])
    params, _ = omniquant_calib.calibrate(params, cfg, calib,
                                          steps_per_layer=steps_per_layer,
                                          lr=5e-3)
    _cache_save(key, params)
    return params, cfg


def _merge_weights(dst, src):
    """Copy every leaf of src into dst where key-paths match."""
    flat_src, _ = jax.tree_util.tree_flatten_with_path(src)
    src_map = {jax.tree_util.keystr(p): v for p, v in flat_src}
    flat_dst, treedef = jax.tree_util.tree_flatten_with_path(dst)
    merged = [src_map.get(jax.tree_util.keystr(p), v) for p, v in flat_dst]
    return jax.tree_util.tree_unflatten(treedef, merged)


def eval_nll(params, cfg, bits, n_batches: int = 4) -> tuple[float, float]:
    """(log pplx, us/call) on held-out data at the given precision.

    Same corpus (same Markov structure), disjoint step range -- the
    held-out set is fresh samples of the SAME language."""
    corpus = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=SEQ, seed=DATA_SEED))
    fwd = jax.jit(lambda p, t: api.forward(p, {"tokens": t}, cfg, bits=bits)[0])
    tot, n = 0.0, 0
    t_us = None
    for i in range(n_batches):
        b = corpus.batch(EVAL_SEED + i, 16, SEQ)
        toks, labels = jnp.asarray(b["tokens"]), jnp.asarray(b["labels"])
        logits = fwd(params, toks)
        if i == 1:  # time a warm call
            t0 = time.perf_counter()
            jax.block_until_ready(fwd(params, toks))
            t_us = (time.perf_counter() - t0) * 1e6
        tot += float(cross_entropy(logits, labels))
        n += 1
    return tot / n, t_us or 0.0


def fmt_rows(rows):
    out = []
    for name, us, derived in rows:
        out.append(f"{name},{us:.1f},{derived:.4f}")
    return "\n".join(out)
