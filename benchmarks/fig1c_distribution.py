"""Figure 1c: MatQuant right-shifts the quantized weight distribution.

derived = mean int8 code over quantized FFN weights; the MatQuant model
should sit to the RIGHT of (above) the baseline's mean code."""

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.quant import QuantConfig

from benchmarks.common import train_qat


def _mean_code(params):
    vals, weights = [], []
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        names = [str(getattr(k, "key", "")) for k in path]
        if names[-1:] == ["w"] and "ffn" in names:
            vals.append(float(quant.right_shift_stat(
                leaf.astype(jnp.float32), 8,
                axis=1 if leaf.ndim == 3 else 0)))
            weights.append(leaf.size)
    tot = sum(weights)
    return sum(v * w for v, w in zip(vals, weights)) / tot


def run():
    mat, _ = train_qat(QuantConfig(mode="qat", bitwidths=(8, 4, 2),
                                   weights=(0.1, 0.1, 1.0)), tag="t2mat")
    base, _ = train_qat(QuantConfig(mode="qat", bitwidths=(8,),
                                    weights=(1.0,)), tag="t2b8")
    return [
        ("fig1c/mean_int8_code/matquant", 0.0, _mean_code(mat)),
        ("fig1c/mean_int8_code/baseline_int8", 0.0, _mean_code(base)),
    ]
