"""Figure 2/3: layer-wise Mix'n'Match Pareto sweep on the MatQuant model.

derived = log pplx at each effective-bits point (pyramid strategy, the
paper's winner), demonstrating the dense accuracy-vs-cost trade-off."""

from repro.core import mixnmatch
from repro.core.quant import QuantConfig
from repro.models import api

import jax.numpy as jnp

from benchmarks.common import eval_nll, train_qat


def run():
    mat, cfg = train_qat(QuantConfig(mode="qat", bitwidths=(8, 4, 2),
                                     weights=(0.1, 0.1, 1.0)), tag="t2mat")
    rows = []
    for eff, assignment in mixnmatch.sweep(cfg.num_layers, points=7):
        nll, us = eval_nll(mat, cfg, list(assignment))
        rows.append((f"fig2/mixnmatch/bits_{eff:.2f}", us, nll))
    # strategy comparison at a fixed budget (Appendix B)
    for strat in mixnmatch.STRATEGIES:
        a = mixnmatch.assign(cfg.num_layers, 5.0, strat)
        nll, us = eval_nll(mat, cfg, a)
        rows.append((f"fig2/strategy_{strat}/bits_5.0", us, nll))
    return rows
