"""Benchmark runner: one function per paper table. Prints
``name,us_per_call,derived`` CSV (derived = log pplx unless noted)."""

import sys
import time


TABLES = [
    "table1_omniquant",
    "table2_qat",
    "table3_weightings",
    "table4_codistill",
    "table5_single_precision",
    "table6_ffn_attn",
    "table7_extra_precision",
    "table8_ep_codistill",
    "fig2_mixnmatch",
    "fig1c_distribution",
]


def main() -> None:
    import importlib

    only = sys.argv[1:] or TABLES
    print("name,us_per_call,derived")
    for name in TABLES:
        if name not in only:
            continue
        t0 = time.time()
        mod = importlib.import_module(f"benchmarks.{name}")
        try:
            rows = mod.run()
        except Exception as e:  # keep the suite running
            print(f"{name}/ERROR,0.0,nan  # {type(e).__name__}: {e}",
                  file=sys.stderr)
            continue
        for r in rows:
            print(f"{r[0]},{r[1]:.1f},{r[2]:.4f}")
        print(f"# {name} done in {time.time()-t0:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
