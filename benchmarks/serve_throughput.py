"""Continuous-batching serving benchmark -> BENCH_serve.json.

Replays a Poisson arrival trace through the elastic-precision
continuous-batching scheduler and records throughput (tok/s), mean
TTFT, queue behavior, and per-tier occupancy -- the serving-side
counterpart of the paper-table quality benchmarks, so each PR's
scheduler changes show up as numbers.

Runs reported side by side on the SAME trace:

  * elastic        -- router downgrades int8 -> int4 -> Mix'n'Match ->
    int2+ep -> int2 as the queue builds, recovers as it drains
    (dequantized tiers);
  * fixed          -- int8 only (the quality-maximal baseline);
  * packed A/B     -- the same elastic replay twice, once over PACKED
    r-bit tier planes and once over dequantized tiers, with measured
    per-tier HBM weight bytes (`packed_nbytes`, shrinking per downgrade
    step with the per-layer bit sum) and tok/s -- the paper's Section
    5.4 bytes claim as a reported number instead of an assertion;
  * MoE packed A/B -- the same packed-vs-dequant elastic replay on a
    granite_moe config (expert stacks served as per-expert packed
    planes), so the bytes claim also covers the MoE layout
    (`packed_ab_moe` in BENCH_serve.json);
  * packed ep A/B  -- one PINNED-tier packed replay per ladder rung
    (`packed_ab_ep`): per-tier tok/s next to the measured plane-bytes
    staircase int8 > int4 > mnm > int2+ep > int2 and the Table-7
    effective bits of each tier (int2+ep ~2.05: the Errata Eq. 8
    overflow bitmap costs 1 stored bit/weight but only ~0.05
    *effective* bits, served in-kernel).

Reduced runs serve 4 layers (`--layers`) so the Mix'n'Match tier lands
at 3.5 effective bits -- strictly between int4 and the int2+ep rung's
3.0 stored bits/weight -- keeping the staircase strict.

  PYTHONPATH=src python benchmarks/serve_throughput.py --reduced
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.configs import get_config
from repro.models import api
from repro.serve import Engine, Request, ServeConfig, ServeMetrics
from repro.serve.scheduler import poisson_trace


def tier_bytes(sched) -> dict:
    """Measured per-tier weight footprint from the scheduler's cache."""
    out = {}
    for tier in sched.router.tiers:
        e = sched.tier_cache.get(tier)
        out[tier.name] = {"packed_bits": e.packed_bits,
                          "packed_nbytes": e.packed_nbytes,
                          "weight_nbytes": e.weight_nbytes,
                          "effective_bits": e.effective_bits}
    return out


def _row_buckets(num_slots: int) -> list[int]:
    """Admission-burst row buckets: powers of two up to AND covering
    num_slots (a 5-admission burst on 6 slots pads to 8 rows, so that
    shape needs warming too)."""
    buckets = [1]
    while buckets[-1] < num_slots:
        buckets.append(buckets[-1] * 2)
    return buckets


def _pin_router(sched, index: int):
    """Hold the router at `index`: thresholds at +inf keep the desired
    index at 0 (< index, the calm branch) and the huge cooldown stops
    the calm branch from ever recovering upward."""
    sched.router.thresholds = (float("inf"),) * (len(sched.router.tiers) - 1)
    sched.router.cooldown = 10**9
    sched.router.index = index
    sched._set_tier(sched.router.tier)


def run_once(engine, cfg, args, *, elastic: bool, packed: bool | None = None):
    sched = engine.scheduler(elastic=elastic, thresholds=args.thresholds,
                             cooldown=args.cooldown, packed=packed)
    trace = poisson_trace(cfg, requests=args.requests,
                          prompt_len=args.prompt_len,
                          gen_tokens=args.gen_tokens,
                          rate=args.arrival_rate, seed=args.seed)
    # warm the jitted prefill/decode closures (one per packed
    # representation for packed tiers; one prefill trace per
    # admission-burst row bucket) and the tier materializations so the
    # replay measures steady-state serving.
    if elastic:
        # pin the router: warm bursts would otherwise raise the load
        # signal and re-route mid-warm, leaving some (representation,
        # rows) closure shapes cold and compiling inside the timed replay
        saved = (sched.router.thresholds, sched.router.cooldown)
    for tier_warm in range(len(sched.router.tiers) if elastic else 1):
        if elastic:
            _pin_router(sched, tier_warm)
        for rows in _row_buckets(args.num_slots):
            for j in range(min(rows, args.num_slots)):
                sched.submit(Request(uid=f"_warm{tier_warm}_{rows}_{j}",
                                     prompt=trace[0][1].prompt,
                                     max_new_tokens=2))
            sched.run_until_idle()
    if elastic:
        sched.router.thresholds, sched.router.cooldown = saved
    sched.reset()
    t0 = time.perf_counter()
    results = sched.run_trace(trace)
    wall = time.perf_counter() - t0
    assert len(results) == args.requests, (len(results), args.requests)
    summary = sched.metrics.summary()
    summary["wall_s"] = wall
    summary["prefill_calls"] = sched.prefill_calls
    per_tier = tier_bytes(sched) if elastic else None
    return summary, per_tier


def run_per_tier_packed(engine, cfg, args):
    """`packed_ab_ep`: one pinned-tier packed replay per ladder rung.

    Unlike the elastic A/B (which reports whatever tiers the router
    visited), this serves the WHOLE trace at each tier of the packed
    ladder, so every rung -- including the extra-precision int2+ep one
    -- gets a throughput number next to its measured plane bytes and
    Table-7 effective bits. Returns (per-tier dict in ladder order,
    strictly-decreasing-bytes flag).
    """
    sched = engine.scheduler(elastic=True, thresholds=args.thresholds,
                             cooldown=args.cooldown, packed=True)
    trace = poisson_trace(cfg, requests=args.requests,
                          prompt_len=args.prompt_len,
                          gen_tokens=args.gen_tokens,
                          rate=args.arrival_rate, seed=args.seed)
    tiers = {}
    for idx, tier in enumerate(sched.router.tiers):
        sched.reset()
        _pin_router(sched, idx)
        for rows in _row_buckets(args.num_slots):      # warm this tier
            for j in range(min(rows, args.num_slots)):
                sched.submit(Request(uid=f"_warm{idx}_{rows}_{j}",
                                     prompt=trace[0][1].prompt,
                                     max_new_tokens=2))
            sched.run_until_idle()
        sched.results = {}                 # drop the warm-up requests
        sched.metrics = ServeMetrics()
        results = sched.run_trace(trace)
        assert len(results) == args.requests
        entry = sched.tier_cache.get(tier)
        tiers[tier.name] = {
            "packed_bits": entry.packed_bits,
            "packed_nbytes": entry.packed_nbytes,
            "weight_nbytes": entry.weight_nbytes,
            "effective_bits": entry.effective_bits,
            "throughput_tok_s": sched.metrics.summary()["throughput_tok_s"],
        }
    nbytes = [info["packed_nbytes"] for info in tiers.values()]
    return tiers, all(a > b for a, b in zip(nbytes, nbytes[1:]))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family model (CPU-sized; served at "
                         "--layers layers so the Mix'n'Match tier sits "
                         "strictly between int4 and int2+ep in bytes)")
    ap.add_argument("--layers", type=int, default=4,
                    help="layer count for --reduced runs")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-tokens", type=int, default=12)
    ap.add_argument("--arrival-rate", type=float, default=1000.0)
    ap.add_argument("--num-slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--thresholds", type=float, nargs="*",
                    default=(2, 6, 12, 24))
    ap.add_argument("--cooldown", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-packed-ab", action="store_true",
                    help="skip the packed-vs-dequant elastic A/B replay "
                         "(and the per-tier packed_ab_ep replays)")
    ap.add_argument("--moe-arch", default="granite_moe_1b_a400m",
                    help="MoE config for the second packed A/B "
                         "('none' skips it)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced().replace(num_layers=args.layers)
    params = api.init(jax.random.PRNGKey(args.seed), cfg)
    engine = Engine(params, cfg, ServeConfig(
        bits=8, max_len=args.prompt_len + args.gen_tokens,
        num_slots=args.num_slots, page_size=args.page_size))

    print(f"== elastic tiers, {args.requests} Poisson arrivals "
          f"@ {args.arrival_rate}/s ==")
    elastic, elastic_tiers = run_once(engine, cfg, args, elastic=True)
    print(json.dumps(elastic, indent=2))
    print("== fixed int8, same trace ==")
    fixed, _ = run_once(engine, cfg, args, elastic=False)
    print(json.dumps(fixed, indent=2))

    def _print_tiers(tiers):
        for name, info in tiers.items():
            print(f"  tier {name:16s} packed_bits={info['packed_bits']} "
                  f"packed_nbytes={info['packed_nbytes']:,d} "
                  f"weight_nbytes={info['weight_nbytes']:,d} "
                  f"effective_bits={info['effective_bits']:.2f}")

    packed_ab = None
    if not args.skip_packed_ab:
        print("== packed-vs-dequant elastic A/B, same trace ==")
        packed, packed_tiers = run_once(engine, cfg, args, elastic=True,
                                        packed=True)
        packed_ab = {
            "packed": {"summary": packed, "per_tier": packed_tiers,
                       "throughput_tok_s": packed["throughput_tok_s"]},
            "dequant": {"summary": elastic, "per_tier": elastic_tiers,
                        "throughput_tok_s": elastic["throughput_tok_s"]},
        }
        _print_tiers(packed_tiers)

    packed_ab_moe = None
    if not args.skip_packed_ab and args.moe_arch != "none":
        # the same packed-vs-dequant A/B on a MoE config: expert stacks
        # serve as per-expert packed planes, Mix'n'Match as per-layer
        # planes, so a downgrade moves weight bytes on every layout
        print(f"== MoE packed-vs-dequant elastic A/B ({args.moe_arch}) ==")
        cfg_moe = get_config(args.moe_arch)
        if args.reduced:
            cfg_moe = cfg_moe.reduced().replace(num_layers=args.layers)
        params_moe = api.init(jax.random.PRNGKey(args.seed), cfg_moe)
        engine_moe = Engine(params_moe, cfg_moe, ServeConfig(
            bits=8, max_len=args.prompt_len + args.gen_tokens,
            num_slots=args.num_slots, page_size=args.page_size))
        moe_packed, moe_packed_tiers = run_once(
            engine_moe, cfg_moe, args, elastic=True, packed=True)
        moe_dequant, moe_dequant_tiers = run_once(
            engine_moe, cfg_moe, args, elastic=True, packed=False)
        packed_ab_moe = {
            "arch": args.moe_arch + (" (reduced)" if args.reduced else ""),
            "packed": {"summary": moe_packed, "per_tier": moe_packed_tiers,
                       "throughput_tok_s": moe_packed["throughput_tok_s"]},
            "dequant": {"summary": moe_dequant,
                        "per_tier": moe_dequant_tiers,
                        "throughput_tok_s": moe_dequant["throughput_tok_s"]},
        }
        _print_tiers(moe_packed_tiers)

    packed_ab_ep = None
    if not args.skip_packed_ab:
        print("== per-tier pinned packed replays (extra-precision A/B) ==")
        ep_tiers, decreasing = run_per_tier_packed(engine, cfg, args)
        packed_ab_ep = {"per_tier": ep_tiers,
                        "plane_bytes_strictly_decreasing": decreasing}
        for name, info in ep_tiers.items():
            print(f"  tier {name:16s} packed_nbytes={info['packed_nbytes']:,d} "
                  f"effective_bits={info['effective_bits']:.2f} "
                  f"tok/s={info['throughput_tok_s']:.1f}")
        print(f"  plane-bytes staircase strictly decreasing: {decreasing}")

    report = {
        "bench": "serve_throughput",
        "arch": args.arch + (" (reduced)" if args.reduced else ""),
        "requests": args.requests,
        "prompt_len": args.prompt_len,
        "gen_tokens": args.gen_tokens,
        "arrival_rate_per_s": args.arrival_rate,
        "num_slots": args.num_slots,
        "elastic": elastic,
        "fixed_int8": fixed,
        "packed_ab": packed_ab,
        "packed_ab_moe": packed_ab_moe,
        "packed_ab_ep": packed_ab_ep,
        # headline numbers (the acceptance-criterion fields)
        "throughput_tok_s": elastic["throughput_tok_s"],
        "mean_ttft_s": elastic["mean_ttft_s"],
        "tier_occupancy": elastic["tier_occupancy"],
    }
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {out}")
    return report


if __name__ == "__main__":
    main()
