"""Continuous-batching serving benchmark -> BENCH_serve.json.

Replays a Poisson arrival trace through the elastic-precision
continuous-batching scheduler and records throughput (tok/s), mean
TTFT, queue behavior, and per-tier occupancy -- the serving-side
counterpart of the paper-table quality benchmarks, so each PR's
scheduler changes show up as numbers.

Runs reported side by side on the SAME trace:

  * elastic        -- router downgrades int8 -> int4 -> Mix'n'Match ->
    int2+ep -> int2 as the queue builds, recovers as it drains
    (dequantized tiers);
  * fixed          -- int8 only (the quality-maximal baseline);
  * packed A/B     -- the same elastic replay twice, once over PACKED
    r-bit tier planes and once over dequantized tiers, with measured
    per-tier HBM weight bytes (`packed_nbytes`, shrinking per downgrade
    step with the per-layer bit sum) and tok/s -- the paper's Section
    5.4 bytes claim as a reported number instead of an assertion;
  * MoE packed A/B -- the same packed-vs-dequant elastic replay on a
    granite_moe config (expert stacks served as per-expert packed
    planes), so the bytes claim also covers the MoE layout
    (`packed_ab_moe` in BENCH_serve.json);
  * packed ep A/B  -- one PINNED-tier packed replay per ladder rung
    (`packed_ab_ep`): per-tier tok/s next to the measured plane-bytes
    staircase int8 > int4 > mnm > int2+ep > int2 and the Table-7
    effective bits of each tier (int2+ep ~2.05: the Errata Eq. 8
    overflow bitmap costs 1 stored bit/weight but only ~0.05
    *effective* bits, served in-kernel);
  * spec-decode A/B -- plain packed-int8 replay vs Matryoshka
    self-speculative replays of the same trace (`specdecode_ab`), one
    per draft rung (int4, int2): the draft slice ALIASES the resident
    int8 planes (`extra_plane_nbytes` == 0), greedy acceptance keeps
    the output token-exact (`token_exact`, checked per request), and
    the acceptance bookkeeping -- acceptance rate, mean accepted
    prefix length, verify-model steps vs emitted tokens -- is the
    reported speed story;
  * fused-attend A/B -- the same trace replayed fused-vs-gather per KV
    attend width (`attn_kernel_ab`): the fused Pallas kernel (in-tile
    Matryoshka slice + online softmax off the int8 page store) stays
    token-exact vs the gather+dequant fallback at every width while the
    analytic per-token KV READ bytes walk the 8 > 4 > 2 staircase;
  * TP-sharded A/B  -- the same per-tier pinned packed replays on a
    forced 8-device `(data, model)` host mesh (`packed_ab_tp`, one
    subprocess per model-parallel degree so XLA_FLAGS can pin the
    device count before jax initializes): every rung's measured
    per-device plane bytes are exactly packed_nbytes / model_parallel
    and the per-device staircase stays strictly decreasing -- the
    tensor-parallel memory claim as a reported number;
  * replica-fleet A/B -- the SAME trace through `serve.fleet.Fleet` at
    1/2/4 data-parallel replicas on the forced host mesh (`fleet_ab`,
    one subprocess per replica count, same XLA_FLAGS idiom as the TP
    children): throughput per fleet size with token-exact outputs vs
    the single replica, a load-spike segment where the global
    FleetRouter downgrades SOME replicas while the pinned one keeps
    serving high-precision (`per_replica_downgrade`), and a
    kill-one-replica segment whose drain/requeue path reports
    `requests_lost: 0` with `token_exact_vs_single_replica: true`.

Reduced runs serve 4 layers (`--layers`) so the Mix'n'Match tier lands
at 3.5 effective bits -- strictly between int4 and the int2+ep rung's
3.0 stored bits/weight -- keeping the staircase strict.

Every in-process section that drives a scheduler additionally passes
through `compile_guard.assert_no_recompiles` and records its per-key
closure trace counts in the report's top-level `compile_counts` block
(docs/contracts.md), so a compile-count regression surfaces as a JSON
diff in review.

  PYTHONPATH=src python benchmarks/serve_throughput.py --reduced
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax

import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.runtime.compile_guard import assert_no_recompiles
from repro.serve import (Engine, Request, ServeConfig, ServeMetrics,
                         SpecDecodeConfig)
from repro.serve.scheduler import poisson_trace, shared_prefix_trace
from repro.serve.specdecode import extra_plane_nbytes

# per-section compile counts, assembled into the report's top-level
# `compile_counts` block (docs/contracts.md, "The compile-count
# baseline"): every in-process section that drives a scheduler records
# its per-key closure trace counts here after assert_no_recompiles
# verified the one-compile-per-key contract. The TP sections run in
# subprocesses and are covered by tests/test_serve_tp_packed.py instead.
COMPILE_COUNTS: dict[str, dict] = {}


def _record_compiles(section: str, sched, **expectations) -> None:
    """Trip the compile guard on this section's scheduler and stash the
    verified per-key trace counts under `section`."""
    COMPILE_COUNTS[section] = assert_no_recompiles(sched, **expectations)


def tier_bytes(sched) -> dict:
    """Measured per-tier weight footprint from the scheduler's cache."""
    out = {}
    for tier in sched.router.tiers:
        e = sched.tier_cache.get(tier)
        out[tier.name] = {"packed_bits": e.packed_bits,
                          "packed_nbytes": e.packed_nbytes,
                          "weight_nbytes": e.weight_nbytes,
                          "effective_bits": e.effective_bits,
                          "per_device_plane_nbytes": e.per_device_plane_nbytes}
    return out


def _row_buckets(num_slots: int) -> list[int]:
    """Admission-burst row buckets: powers of two up to AND covering
    num_slots (a 5-admission burst on 6 slots pads to 8 rows, so that
    shape needs warming too)."""
    buckets = [1]
    while buckets[-1] < num_slots:
        buckets.append(buckets[-1] * 2)
    return buckets


def _pin_router(sched, index: int):
    """Hold the router at `index`: thresholds at +inf keep the desired
    index at 0 (< index, the calm branch) and the huge cooldown stops
    the calm branch from ever recovering upward."""
    sched.router.thresholds = (float("inf"),) * (len(sched.router.tiers) - 1)
    sched.router.cooldown = 10**9
    sched.router.index = index
    sched._set_tier(sched.router.tier)


def run_once(engine, cfg, args, *, elastic: bool, packed: bool | None = None,
             section: str | None = None):
    sched = engine.scheduler(elastic=elastic, thresholds=args.thresholds,
                             cooldown=args.cooldown, packed=packed)
    trace = poisson_trace(cfg, requests=args.requests,
                          prompt_len=args.prompt_len,
                          gen_tokens=args.gen_tokens,
                          rate=args.arrival_rate, seed=args.seed)
    # warm the jitted prefill/decode closures (one per packed
    # representation for packed tiers; one prefill trace per
    # admission-burst row bucket) and the tier materializations so the
    # replay measures steady-state serving.
    if elastic:
        # pin the router: warm bursts would otherwise raise the load
        # signal and re-route mid-warm, leaving some (representation,
        # rows) closure shapes cold and compiling inside the timed replay
        saved = (sched.router.thresholds, sched.router.cooldown)
    for tier_warm in range(len(sched.router.tiers) if elastic else 1):
        if elastic:
            _pin_router(sched, tier_warm)
        for rows in _row_buckets(args.num_slots):
            for j in range(min(rows, args.num_slots)):
                sched.submit(Request(uid=f"_warm{tier_warm}_{rows}_{j}",
                                     prompt=trace[0][1].prompt,
                                     max_new_tokens=2))
            sched.run_until_idle()
    if elastic:
        sched.router.thresholds, sched.router.cooldown = saved
    sched.reset()
    t0 = time.perf_counter()
    results = sched.run_trace(trace)
    wall = time.perf_counter() - t0
    assert len(results) == args.requests, (len(results), args.requests)
    summary = sched.metrics.summary()
    summary["wall_s"] = wall
    summary["prefill_calls"] = sched.prefill_calls
    per_tier = tier_bytes(sched) if elastic else None
    if section is not None:
        # dequant replays (fixed or elastic) share the single key None;
        # packed replays key per representation -- leave the set open
        dequant = not (engine.packed if packed is None else packed)
        _record_compiles(section, sched,
                         expect_keys={None} if dequant else None)
    return summary, per_tier


def run_per_tier_packed(engine, cfg, args):
    """`packed_ab_ep`: one pinned-tier packed replay per ladder rung.

    Unlike the elastic A/B (which reports whatever tiers the router
    visited), this serves the WHOLE trace at each tier of the packed
    ladder, so every rung -- including the extra-precision int2+ep one
    -- gets a throughput number next to its measured plane bytes and
    Table-7 effective bits. Returns (per-tier dict in ladder order,
    strictly-decreasing-bytes flag).
    """
    sched = engine.scheduler(elastic=True, thresholds=args.thresholds,
                             cooldown=args.cooldown, packed=True)
    trace = poisson_trace(cfg, requests=args.requests,
                          prompt_len=args.prompt_len,
                          gen_tokens=args.gen_tokens,
                          rate=args.arrival_rate, seed=args.seed)
    tiers = {}
    for idx, tier in enumerate(sched.router.tiers):
        sched.reset()
        _pin_router(sched, idx)
        for rows in _row_buckets(args.num_slots):      # warm this tier
            for j in range(min(rows, args.num_slots)):
                sched.submit(Request(uid=f"_warm{idx}_{rows}_{j}",
                                     prompt=trace[0][1].prompt,
                                     max_new_tokens=2))
            sched.run_until_idle()
        sched.results = {}                 # drop the warm-up requests
        sched.metrics = ServeMetrics()
        results = sched.run_trace(trace)
        assert len(results) == args.requests
        entry = sched.tier_cache.get(tier)
        tiers[tier.name] = {
            "packed_bits": entry.packed_bits,
            "packed_nbytes": entry.packed_nbytes,
            "weight_nbytes": entry.weight_nbytes,
            "effective_bits": entry.effective_bits,
            "per_device_plane_nbytes": entry.per_device_plane_nbytes,
            "throughput_tok_s": sched.metrics.summary()["throughput_tok_s"],
        }
    nbytes = [info["packed_nbytes"] for info in tiers.values()]
    _record_compiles("packed_ab_ep", sched)
    return tiers, all(a > b for a, b in zip(nbytes, nbytes[1:]))


def _replay_pinned_int8(engine, args, trace, spec=None):
    """One packed int8-pinned replay of `trace` (warmed), optionally
    self-speculative. Returns (scheduler, results, summary)."""
    sched = engine.scheduler(elastic=True, thresholds=args.thresholds,
                             cooldown=args.cooldown, packed=True,
                             spec_decode=spec)
    _pin_router(sched, 0)                        # int8 = top of the ladder
    for rows in _row_buckets(args.num_slots):    # warm closures (draft/
        for j in range(min(rows, args.num_slots)):   # verify ones too)
            sched.submit(Request(uid=f"_warm_{rows}_{j}",
                                 prompt=trace[0][1].prompt,
                                 max_new_tokens=2))
        sched.run_until_idle()
    sched.results = {}
    sched.metrics = ServeMetrics()
    t0 = time.perf_counter()
    results = sched.run_trace(trace)
    wall = time.perf_counter() - t0
    assert len(results) == args.requests
    summary = sched.metrics.summary()
    summary["wall_s"] = wall
    return sched, results, summary


def run_specdecode_ab(engine, cfg, args):
    """`specdecode_ab`: plain packed-int8 replay vs Matryoshka
    self-speculative replays of the SAME trace, one per draft rung.

    Greedy acceptance makes each spec replay token-exact vs the plain
    one (reported as `token_exact`, checked per request), so the A/B
    isolates the speed bookkeeping: acceptance rate, mean accepted
    prefix length (> 1.0 means drafts help), verify-model steps vs
    emitted tokens, and the aliased draft plane's extra bytes (0 on the
    packed path -- the draft is a `sliced_view` of the resident int8
    planes).
    """
    trace = poisson_trace(cfg, requests=args.requests,
                          prompt_len=args.prompt_len,
                          gen_tokens=args.gen_tokens,
                          rate=args.arrival_rate, seed=args.seed)
    plain_sched, plain_results, plain_summary = _replay_pinned_int8(
        engine, args, trace)
    _record_compiles("specdecode_ab.plain", plain_sched)
    out = {"verify_tier": "int8 (packed)",
           "draft_len": args.draft_len,
           "plain": {"summary": plain_summary,
                     "throughput_tok_s": plain_summary["throughput_tok_s"]}}
    for tier_name in args.draft_tiers:
        from repro.launch.serve import parse_draft_tier
        bits, ep = parse_draft_tier(tier_name)
        spec = SpecDecodeConfig(draft_bits=bits, draft_extra_precision=ep,
                                draft_len=args.draft_len)
        sched, results, summary = _replay_pinned_int8(engine, args, trace,
                                                      spec=spec)
        _record_compiles(f"specdecode_ab.{tier_name}", sched)
        draft_params, _ = sched._spec_draft()
        spec_sum = summary["spec"]
        out[tier_name] = {
            "summary": summary,
            "throughput_tok_s": summary["throughput_tok_s"],
            "token_exact": all(
                np.array_equal(results[uid], plain_results[uid])
                for uid in plain_results),
            "acceptance_rate": spec_sum["acceptance_rate"],
            "mean_accepted_prefix_len": spec_sum["mean_accepted_prefix_len"],
            "verify_steps": spec_sum["verify_steps"],
            "emitted_tokens": spec_sum["emitted_tokens"],
            "verify_steps_below_tokens": (
                spec_sum["verify_steps"] < spec_sum["emitted_tokens"]),
            "extra_plane_nbytes": extra_plane_nbytes(draft_params,
                                                     sched.params),
        }
    return out


def run_tp_child(args):
    """`--tp-child MP` mode: the per-tier pinned packed replay on a
    (data, model) host mesh, run in a SUBPROCESS so the forced host
    device count (XLA_FLAGS) is set before jax initializes. Writes the
    `packed_ab_tp` fragment for one model-parallel degree to --out."""
    from repro.launch.mesh import make_host_mesh
    mp = args.tp_child
    mesh = make_host_mesh(mp)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced().replace(num_layers=args.layers)
    params = api.init(jax.random.PRNGKey(args.seed), cfg)
    engine = Engine(params, cfg, ServeConfig(
        bits=8, max_len=args.prompt_len + args.gen_tokens,
        num_slots=args.num_slots, page_size=args.page_size), mesh=mesh)
    tiers, decreasing = run_per_tier_packed(engine, cfg, args)
    per_dev = [info["per_device_plane_nbytes"] for info in tiers.values()]
    fragment = {
        "model_parallel": mp,
        "devices": len(jax.devices()),
        "per_tier": tiers,
        "plane_bytes_strictly_decreasing": decreasing,
        "per_device_plane_bytes_strictly_decreasing": all(
            a > b for a, b in zip(per_dev, per_dev[1:])),
        # the TP claim as a reported number: every rung's per-device
        # footprint is exactly its total plane bytes / model_parallel
        "per_device_equals_total_over_mp": all(
            info["per_device_plane_nbytes"] * mp == info["packed_nbytes"]
            for info in tiers.values()),
    }
    with open(args.out, "w") as f:
        json.dump(fragment, f, indent=2)
    return fragment


def run_tp_ab(args) -> dict:
    """`packed_ab_tp`: re-invoke this benchmark as a subprocess per
    model-parallel degree on a forced `--tp-devices`-device CPU host
    mesh (the device count must be pinned before jax is imported, which
    an in-process run cannot do) and merge the fragments."""
    import subprocess
    import sys
    import tempfile

    # benchmarks/ sits next to src/ (repro is a namespace package, so
    # its __file__ is None -- derive the import root from this file)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    out = {}
    with tempfile.TemporaryDirectory() as tmp_dir:
        for mp in args.tp_model_parallel:
            frag_path = os.path.join(tmp_dir, f"tp{mp}.json")
            env = dict(os.environ)
            env["JAX_PLATFORMS"] = "cpu"
            env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                                f" --xla_force_host_platform_device_count="
                                f"{args.tp_devices}").strip()
            env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
            cmd = [sys.executable, os.path.abspath(__file__),
                   "--tp-child", str(mp), "--arch", args.arch,
                   "--layers", str(args.layers),
                   "--requests", str(args.tp_requests),
                   "--prompt-len", str(args.prompt_len),
                   "--gen-tokens", str(args.gen_tokens),
                   "--arrival-rate", str(args.arrival_rate),
                   "--num-slots", str(args.num_slots),
                   "--page-size", str(args.page_size),
                   "--cooldown", str(args.cooldown),
                   "--seed", str(args.seed),
                   "--thresholds", *map(str, args.thresholds),
                   "--out", frag_path]
            if args.reduced:
                cmd.append("--reduced")
            proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
            if proc.returncode != 0:
                raise RuntimeError(
                    f"packed_ab_tp child (model_parallel={mp}) failed:\n"
                    + proc.stderr[-2000:])
            with open(frag_path) as f:
                out[f"mp{mp}"] = json.load(f)
    return out


def run_fleet_child(args):
    """`--fleet-child R` mode: one fleet segment on a forced host mesh,
    run in a SUBPROCESS (same XLA_FLAGS idiom as the TP children) so
    every replica owns a disjoint device subset. Segments:

      * throughput -- the shared trace, tiers pinned at int8;
      * spike      -- the default threshold ramp under the same burst,
        so the global router downgrades SOME replicas;
      * kill       -- tiers pinned, one replica hard-killed mid-replay
        to exercise the drain/requeue path.

    Writes the fragment (summary + per-request tokens, so the parent
    can check token-exactness across fleet sizes) to --out."""
    from repro.serve import FleetMetrics
    from repro.serve.fleet import build_fleet
    from repro.serve.router import default_tiers

    num_replicas = args.fleet_child
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced().replace(num_layers=args.layers)
    params = api.init(jax.random.PRNGKey(args.seed), cfg)
    tiers = default_tiers(cfg.num_layers)
    steps = num_replicas * (len(tiers) - 1)
    thresholds = (tuple(4.0 * (s + 1) for s in range(steps))
                  if args.fleet_segment == "spike"
                  else (float("inf"),) * steps)
    fleet = build_fleet(params, cfg, replicas=num_replicas,
                        num_slots=args.num_slots,
                        max_len=args.prompt_len + args.gen_tokens,
                        tiers=tiers, thresholds=thresholds,
                        cooldown=args.cooldown, pinned=(0,))
    trace = poisson_trace(cfg, requests=args.fleet_requests,
                          prompt_len=args.prompt_len,
                          gen_tokens=args.gen_tokens,
                          rate=args.arrival_rate, seed=args.seed)
    # warm every replica's closures directly (bypassing the global
    # queue, so fleet metrics stay clean); the spike segment visits
    # every tier so mid-replay downgrades are cache hits
    tier_range = (range(len(tiers)) if args.fleet_segment == "spike"
                  else (0,))
    for rep in fleet.replicas:
        for idx in tier_range:
            rep.set_tier(idx)
            for rows in _row_buckets(args.num_slots):
                for j in range(min(rows, args.num_slots)):
                    rep.submit(Request(
                        uid=f"_warm{rep.rid}_{idx}_{rows}_{j}",
                        prompt=trace[0][1].prompt, max_new_tokens=2))
                while rep.inflight():
                    rep.step()
        rep.set_tier(0)
    fleet.results = {}
    fleet.metrics = FleetMetrics()
    fleet.router.reset()
    fleet._applied = [0] * num_replicas

    killed = []

    def on_step(f, step_index):
        if (args.fleet_segment == "kill" and not killed
                and step_index == args.fleet_kill_step):
            f.kill(num_replicas - 1)       # an unpinned replica
            killed.append(step_index)

    t0 = time.perf_counter()
    results = fleet.run_trace(trace, on_step=on_step)
    wall = time.perf_counter() - t0
    assert len(results) == args.fleet_requests, (len(results),
                                                 args.fleet_requests)
    summary = fleet.metrics.summary()
    compile_counts = {}
    for rep in fleet.replicas:
        expect = None if rep.engine.packed else {None}
        compile_counts[f"replica{rep.rid}"] = assert_no_recompiles(
            rep.sched, expect_keys=expect)
    fleet.close()
    fragment = {
        "replicas": num_replicas,
        "segment": args.fleet_segment,
        "devices": len(jax.devices()),
        "wall_s": wall,
        "throughput_tok_s": summary["throughput_tok_s"],
        "requests_lost": summary["requests_lost"],
        "summary": summary,
        "tokens": {str(uid): [int(t) for t in toks]
                   for uid, toks in results.items()},
        "compile_counts": compile_counts,
    }
    if args.fleet_segment == "spike":
        occ = {rid: info["tier_occupancy"]
               for rid, info in summary["per_replica"].items()}
        downgraded = sum(1 for o in occ.values()
                         if any(t != tiers[0].name for t in o))
        fragment["tier_occupancy_by_replica"] = occ
        fragment["downgraded_replicas"] = downgraded
        # the fleet-policy claim: a load spike costs SOME replicas
        # precision, never the whole fleet
        fragment["per_replica_downgrade"] = 0 < downgraded < num_replicas
    if args.fleet_segment == "kill":
        fragment["requeued_requests"] = summary["requeued_requests"]
        fragment["replica_failures"] = summary["replica_failures"]
    with open(args.out, "w") as f:
        json.dump(fragment, f, indent=2)
    return fragment


def run_fleet_ab(args) -> dict:
    """`fleet_ab`: the replica-fleet study -- one subprocess per
    (replica count, segment) on a forced `--fleet-devices` host mesh,
    fragments merged parent-side (token-exactness across fleet sizes is
    checked HERE, where every fragment's tokens are in hand)."""
    import subprocess
    import sys
    import tempfile

    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")

    def child(num_replicas, segment, frag_path):
        env = dict(os.environ)
        env["JAX_PLATFORMS"] = "cpu"
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count="
                            f"{args.fleet_devices}").strip()
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        cmd = [sys.executable, os.path.abspath(__file__),
               "--fleet-child", str(num_replicas),
               "--fleet-segment", segment,
               "--fleet-requests", str(args.fleet_requests),
               "--fleet-kill-step", str(args.fleet_kill_step),
               "--arch", args.arch, "--layers", str(args.layers),
               "--prompt-len", str(args.prompt_len),
               "--gen-tokens", str(args.gen_tokens),
               "--arrival-rate", str(args.arrival_rate),
               "--num-slots", str(args.num_slots),
               "--cooldown", str(args.cooldown),
               "--seed", str(args.seed), "--out", frag_path]
        if args.reduced:
            cmd.append("--reduced")
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
        if proc.returncode != 0:
            raise RuntimeError(
                f"fleet_ab child (replicas={num_replicas}, {segment}) "
                f"failed:\n" + proc.stderr[-2000:])
        with open(frag_path) as f:
            return json.load(f)

    out = {"devices_forced": args.fleet_devices}
    with tempfile.TemporaryDirectory() as tmp_dir:
        frags = {}
        for num_replicas in args.fleet_replicas:
            frags[num_replicas] = child(
                num_replicas, "throughput",
                os.path.join(tmp_dir, f"fleet{num_replicas}.json"))
        base = frags.get(1)
        out["throughput"] = {
            f"r{n}": {
                "replicas": n,
                "throughput_tok_s": frag["throughput_tok_s"],
                "wall_s": frag["wall_s"],
                "requests_lost": frag["requests_lost"],
                "compile_counts": frag["compile_counts"],
                **({"token_exact_vs_single_replica":
                    frag["tokens"] == base["tokens"]} if base else {}),
            }
            for n, frag in frags.items()
        }
        spike = child(max(args.fleet_replicas), "spike",
                      os.path.join(tmp_dir, "fleet_spike.json"))
        out["load_spike"] = {
            "replicas": spike["replicas"],
            "requests_lost": spike["requests_lost"],
            "tier_occupancy_by_replica": spike["tier_occupancy_by_replica"],
            "downgraded_replicas": spike["downgraded_replicas"],
            "per_replica_downgrade": spike["per_replica_downgrade"],
            "mean_effective_bits_min":
                spike["summary"]["mean_effective_bits_min"],
            "tier_switches": spike["summary"]["tier_switches"],
        }
        kill = child(2, "kill", os.path.join(tmp_dir, "fleet_kill.json"))
        out["kill_one_replica"] = {
            "replicas": 2,
            "requests_lost": kill["requests_lost"],
            "requeued_requests": kill["requeued_requests"],
            "replica_failures": kill["replica_failures"],
            "throughput_tok_s": kill["throughput_tok_s"],
            **({"token_exact_vs_single_replica":
                kill["tokens"] == base["tokens"]} if base else {}),
        }
    return out


def _warm_and_replay(engine, args, trace, section: str | None = None):
    """Fixed-tier scheduler over one paged engine: warm the closures on
    every admission row bucket, then replay `trace` timed."""
    sched = engine.scheduler()
    for rows in _row_buckets(args.num_slots):
        for j in range(min(rows, args.num_slots)):
            sched.submit(Request(uid=f"_warm_{rows}_{j}",
                                 prompt=trace[0][1].prompt,
                                 max_new_tokens=2))
        sched.run_until_idle()
    if engine.serve_cfg.prefix_cache:
        # the hit path compiles per (suffix bucket, row bucket) plus the
        # COW copy buckets -- replay the trace once untimed so the timed
        # pass (and its hit-vs-cold TTFT split) measures serving, not
        # tracing
        sched.reset()
        sched.run_trace(trace)
    sched.reset()
    t0 = time.perf_counter()
    results = sched.run_trace(trace)
    wall = time.perf_counter() - t0
    summary = sched.metrics.summary()
    summary["wall_s"] = wall
    if section is not None:
        _record_compiles(section, sched)
    return results, summary


def run_kv_ab(params, cfg, args) -> dict:
    """`kv_ab`: the paged Matryoshka KV cache as reported numbers.

    Three sub-studies on fixed-int8 weights (so only the KV layout
    varies):

      * per-bits replays of the SAME Poisson trace over dense KV, fp
        pages, and int8 pages attended at the 8/4/2-bit Matryoshka
        slices -- per-token KV bytes must form the staircase
        int8 > int4 > int2 (`kv_bytes_strictly_decreasing`), and the
        fp-paged replay must be token-identical to dense
        (`fp_token_exact`, the refactor's exactness gate);
      * a shared-system-prompt trace (every prompt = one common prefix
        + its own suffix) replayed with the radix prefix cache ON vs
        OFF: hit rate, shared-token rate, and the hit-vs-cold TTFT
        split -- hits prefill only their suffix, so mean hit TTFT must
        sit below mean cold TTFT (`ttft_hit_below_cold`).
    """
    base = dict(bits=8, max_len=args.prompt_len + args.gen_tokens,
                num_slots=args.num_slots, page_size=args.page_size)
    trace = poisson_trace(cfg, requests=args.requests,
                          prompt_len=args.prompt_len,
                          gen_tokens=args.gen_tokens,
                          rate=args.arrival_rate, seed=args.seed)
    per_bits = {}
    dense_results = None
    for kv_bits in ("dense", "fp", 8, 4, 2):
        engine = Engine(params, cfg, ServeConfig(
            **base, kv_bits=None if kv_bits == "dense" else kv_bits))
        results, summary = _warm_and_replay(engine, args, trace,
                                            section=f"kv_ab.{kv_bits}")
        assert len(results) == args.requests
        if kv_bits == "dense":
            dense_results = results
        per_bits[str(kv_bits)] = {
            "throughput_tok_s": summary["throughput_tok_s"],
            "mean_ttft_s": summary["mean_ttft_s"],
            "wall_s": summary["wall_s"],
            "kv": summary["kv"],
            "token_exact_vs_dense": all(
                np.array_equal(results[uid], dense_results[uid])
                for uid in dense_results),
        }
    staircase = [per_bits[b]["kv"]["bytes_per_token"] for b in ("8", "4", "2")]

    # prefix A/B: a chatbot-style trace -- a long shared system prompt
    # (12x the per-request suffix, like real system prompts) so the
    # suffix-only hit prefill saving dominates the page-gather overhead
    # even at CPU-reduced scale, incl. for hits admitted in a batched
    # multi-row group (whose whole group prefill counts against each
    # member's TTFT)
    prefix_len = max(args.page_size * 2, args.prompt_len * 12)
    ptrace = shared_prefix_trace(cfg, requests=args.requests,
                                 prefix_len=prefix_len,
                                 suffix_len=args.prompt_len,
                                 gen_tokens=args.gen_tokens,
                                 rate=args.arrival_rate, seed=args.seed)
    prefix_ab = {}
    for on in (False, True):
        engine = Engine(params, cfg, ServeConfig(
            bits=8, max_len=prefix_len + args.prompt_len + args.gen_tokens,
            num_slots=args.num_slots, page_size=args.page_size,
            kv_bits="fp", prefix_cache=on))
        results, summary = _warm_and_replay(
            engine, args, ptrace,
            section=f"kv_ab.prefix_{'on' if on else 'off'}")
        assert len(results) == args.requests
        kv = summary["kv"]
        prefix_ab["on" if on else "off"] = {
            "throughput_tok_s": summary["throughput_tok_s"],
            "mean_ttft_s": summary["mean_ttft_s"],
            "prefix_hit_rate": kv["prefix_hit_rate"],
            "shared_token_rate": kv["shared_token_rate"],
            "mean_ttft_hit_s": kv["mean_ttft_hit_s"],
            "mean_ttft_cold_s": kv["mean_ttft_cold_s"],
            "mean_prefill_ttft_hit_s": kv["mean_prefill_ttft_hit_s"],
            "mean_prefill_ttft_cold_s": kv["mean_prefill_ttft_cold_s"],
            "kv": kv,
        }
    on = prefix_ab["on"]
    return {
        "weights": "int8 (dequantized fixed tier)",
        "per_bits": per_bits,
        "kv_bytes_per_token": {b: per_bits[b]["kv"]["bytes_per_token"]
                               for b in ("fp", "8", "4", "2")},
        "kv_bytes_strictly_decreasing": all(
            a > b for a, b in zip(staircase, staircase[1:])),
        "fp_token_exact": per_bits["fp"]["token_exact_vs_dense"],
        "prefix_ab": prefix_ab,
        "prefix_hit_rate": on["prefix_hit_rate"],
        # prefill (admission -> first token) latency isolates the
        # suffix-only prefill saving from queueing delay
        "ttft_hit_below_cold": (on["mean_prefill_ttft_hit_s"]
                                < on["mean_prefill_ttft_cold_s"]),
    }


def run_attn_kernel_ab(params, cfg, args) -> dict:
    """`attn_kernel_ab`: fused Pallas paged attention vs the gather+
    dequant fallback as reported numbers.

    The SAME Poisson trace replays through two engines per attend width
    (kv_bits in fp/8/4/2) differing ONLY in `--attn-kernel`: the fused
    kernel attends straight off the int8 page store (in-tile Matryoshka
    slice + online softmax, no bf16 cache view in HBM) while the gather
    path materializes the dequantized slot view first. Reported per
    width: decode tok/s for both kernels, `token_exact_vs_gather`
    (the fused path is a pure performance knob -- checked per request),
    and the analytic per-token KV READ bytes of the attend slice, which
    must form the staircase int8 > int4 > int2 next to the constant
    RESIDENT bytes (the fused kernel's whole point: attending at r bits
    reads r-bit bytes while the parent store stays int8).
    """
    base = dict(bits=8, max_len=args.prompt_len + args.gen_tokens,
                num_slots=args.num_slots, page_size=args.page_size)
    trace = poisson_trace(cfg, requests=args.requests,
                          prompt_len=args.prompt_len,
                          gen_tokens=args.gen_tokens,
                          rate=args.arrival_rate, seed=args.seed)
    per_bits = {}
    for kv_bits in ("fp", 8, 4, 2):
        runs = {}
        for kernel in ("fused", "gather"):
            engine = Engine(params, cfg, ServeConfig(
                **base, kv_bits=kv_bits, attn_kernel=kernel))
            results, summary = _warm_and_replay(
                engine, args, trace,
                section=f"attn_kernel_ab.{kv_bits}.{kernel}")
            assert len(results) == args.requests
            runs[kernel] = (results, summary)
        fused_res, fused_sum = runs["fused"]
        gather_res, gather_sum = runs["gather"]
        per_bits[str(kv_bits)] = {
            "fused": {"throughput_tok_s": fused_sum["throughput_tok_s"],
                      "mean_ttft_s": fused_sum["mean_ttft_s"],
                      "wall_s": fused_sum["wall_s"]},
            "gather": {"throughput_tok_s": gather_sum["throughput_tok_s"],
                       "mean_ttft_s": gather_sum["mean_ttft_s"],
                       "wall_s": gather_sum["wall_s"]},
            "token_exact_vs_gather": all(
                np.array_equal(fused_res[uid], gather_res[uid])
                for uid in gather_res),
            "kv_read_bytes_per_token": fused_sum["kv"]["bytes_read_per_token"],
            "kv_resident_bytes_per_token":
                fused_sum["kv"]["resident_bytes_per_token"],
        }
    read_stairs = [per_bits[b]["kv_read_bytes_per_token"]
                   for b in ("8", "4", "2")]
    assert all(a > b for a, b in zip(read_stairs, read_stairs[1:])), \
        f"KV read-bytes staircase not strictly decreasing: {read_stairs}"
    return {
        "weights": "int8 (dequantized fixed tier)",
        "per_bits": per_bits,
        "kv_read_bytes_per_token": {b: per_bits[b]["kv_read_bytes_per_token"]
                                    for b in ("fp", "8", "4", "2")},
        "kv_read_bytes_strictly_decreasing": all(
            a > b for a, b in zip(read_stairs, read_stairs[1:])),
        "token_exact_all_widths": all(
            info["token_exact_vs_gather"] for info in per_bits.values()),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family model (CPU-sized; served at "
                         "--layers layers so the Mix'n'Match tier sits "
                         "strictly between int4 and int2+ep in bytes)")
    ap.add_argument("--layers", type=int, default=4,
                    help="layer count for --reduced runs")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-tokens", type=int, default=12)
    ap.add_argument("--arrival-rate", type=float, default=1000.0)
    ap.add_argument("--num-slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--thresholds", type=float, nargs="*",
                    default=(2, 6, 12, 24))
    ap.add_argument("--cooldown", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-packed-ab", action="store_true",
                    help="skip the packed-vs-dequant elastic A/B replay "
                         "(and the per-tier packed_ab_ep replays)")
    ap.add_argument("--skip-kv-ab", action="store_true",
                    help="skip the paged-KV A/B section (per-bits KV "
                         "replays + the prefix-cache on/off replay)")
    ap.add_argument("--skip-attn-ab", action="store_true",
                    help="skip the fused-vs-gather paged-attention A/B "
                         "section (attn_kernel_ab)")
    ap.add_argument("--moe-arch", default="granite_moe_1b_a400m",
                    help="MoE config for the second packed A/B "
                         "('none' skips it)")
    ap.add_argument("--tp-model-parallel", type=int, nargs="*",
                    default=(2, 4),
                    help="model-parallel degrees for the packed_ab_tp "
                         "section (per-tier pinned packed replays on a "
                         "forced --tp-devices host mesh; empty skips it)")
    ap.add_argument("--tp-devices", type=int, default=8,
                    help="host device count forced (via XLA_FLAGS, in a "
                         "subprocess) for the packed_ab_tp section")
    ap.add_argument("--tp-requests", type=int, default=8,
                    help="trace length for each packed_ab_tp replay "
                         "(8-device CPU meshes simulate slowly)")
    ap.add_argument("--draft-tiers", nargs="*", default=("int4", "int2"),
                    help="draft rungs for the specdecode_ab section "
                         "(intN / intN+ep; empty skips it)")
    ap.add_argument("--draft-len", type=int, default=4,
                    help="k, draft tokens per verify step (specdecode_ab)")
    ap.add_argument("--fleet-replicas", type=int, nargs="*", default=(1, 2, 4),
                    help="fleet sizes for the fleet_ab throughput segment "
                         "(one subprocess each on a forced --fleet-devices "
                         "host mesh; empty skips the section)")
    ap.add_argument("--fleet-devices", type=int, default=8,
                    help="host device count forced (via XLA_FLAGS, in a "
                         "subprocess) for the fleet_ab section")
    ap.add_argument("--fleet-requests", type=int, default=10,
                    help="trace length for each fleet_ab replay "
                         "(forced-host CPU meshes simulate slowly)")
    ap.add_argument("--fleet-kill-step", type=int, default=3,
                    help="fleet step at which the fleet_ab kill segment "
                         "hard-kills its victim replica")
    ap.add_argument("--skip-fleet-ab", action="store_true",
                    help="skip the replica-fleet A/B section")
    ap.add_argument("--tp-child", type=int, default=0, help=argparse.SUPPRESS)
    ap.add_argument("--fleet-child", type=int, default=0,
                    help=argparse.SUPPRESS)
    ap.add_argument("--fleet-segment", default="throughput",
                    choices=("throughput", "spike", "kill"),
                    help=argparse.SUPPRESS)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    if args.tp_child:
        return run_tp_child(args)
    if args.fleet_child:
        return run_fleet_child(args)

    COMPILE_COUNTS.clear()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced().replace(num_layers=args.layers)
    params = api.init(jax.random.PRNGKey(args.seed), cfg)
    engine = Engine(params, cfg, ServeConfig(
        bits=8, max_len=args.prompt_len + args.gen_tokens,
        num_slots=args.num_slots, page_size=args.page_size))

    print(f"== elastic tiers, {args.requests} Poisson arrivals "
          f"@ {args.arrival_rate}/s ==")
    elastic, elastic_tiers = run_once(engine, cfg, args, elastic=True,
                                      section="elastic")
    print(json.dumps(elastic, indent=2))
    print("== fixed int8, same trace ==")
    fixed, _ = run_once(engine, cfg, args, elastic=False,
                        section="fixed_int8")
    print(json.dumps(fixed, indent=2))

    def _print_tiers(tiers):
        for name, info in tiers.items():
            print(f"  tier {name:16s} packed_bits={info['packed_bits']} "
                  f"packed_nbytes={info['packed_nbytes']:,d} "
                  f"weight_nbytes={info['weight_nbytes']:,d} "
                  f"effective_bits={info['effective_bits']:.2f}")

    packed_ab = None
    if not args.skip_packed_ab:
        print("== packed-vs-dequant elastic A/B, same trace ==")
        packed, packed_tiers = run_once(engine, cfg, args, elastic=True,
                                        packed=True,
                                        section="packed_ab.packed")
        packed_ab = {
            "packed": {"summary": packed, "per_tier": packed_tiers,
                       "throughput_tok_s": packed["throughput_tok_s"]},
            "dequant": {"summary": elastic, "per_tier": elastic_tiers,
                        "throughput_tok_s": elastic["throughput_tok_s"]},
        }
        _print_tiers(packed_tiers)

    packed_ab_moe = None
    if not args.skip_packed_ab and args.moe_arch != "none":
        # the same packed-vs-dequant A/B on a MoE config: expert stacks
        # serve as per-expert packed planes, Mix'n'Match as per-layer
        # planes, so a downgrade moves weight bytes on every layout
        print(f"== MoE packed-vs-dequant elastic A/B ({args.moe_arch}) ==")
        cfg_moe = get_config(args.moe_arch)
        if args.reduced:
            cfg_moe = cfg_moe.reduced().replace(num_layers=args.layers)
        params_moe = api.init(jax.random.PRNGKey(args.seed), cfg_moe)
        engine_moe = Engine(params_moe, cfg_moe, ServeConfig(
            bits=8, max_len=args.prompt_len + args.gen_tokens,
            num_slots=args.num_slots, page_size=args.page_size))
        moe_packed, moe_packed_tiers = run_once(
            engine_moe, cfg_moe, args, elastic=True, packed=True,
            section="packed_ab_moe.packed")
        moe_dequant, moe_dequant_tiers = run_once(
            engine_moe, cfg_moe, args, elastic=True, packed=False,
            section="packed_ab_moe.dequant")
        packed_ab_moe = {
            "arch": args.moe_arch + (" (reduced)" if args.reduced else ""),
            "packed": {"summary": moe_packed, "per_tier": moe_packed_tiers,
                       "throughput_tok_s": moe_packed["throughput_tok_s"]},
            "dequant": {"summary": moe_dequant,
                        "per_tier": moe_dequant_tiers,
                        "throughput_tok_s": moe_dequant["throughput_tok_s"]},
        }
        _print_tiers(moe_packed_tiers)

    packed_ab_ep = None
    if not args.skip_packed_ab:
        print("== per-tier pinned packed replays (extra-precision A/B) ==")
        ep_tiers, decreasing = run_per_tier_packed(engine, cfg, args)
        packed_ab_ep = {"per_tier": ep_tiers,
                        "plane_bytes_strictly_decreasing": decreasing}
        for name, info in ep_tiers.items():
            print(f"  tier {name:16s} packed_nbytes={info['packed_nbytes']:,d} "
                  f"effective_bits={info['effective_bits']:.2f} "
                  f"tok/s={info['throughput_tok_s']:.1f}")
        print(f"  plane-bytes staircase strictly decreasing: {decreasing}")

    specdecode_ab = None
    if not args.skip_packed_ab and args.draft_tiers:
        print("== self-speculative decoding A/B (packed int8 verify) ==")
        specdecode_ab = run_specdecode_ab(engine, cfg, args)
        for name in args.draft_tiers:
            info = specdecode_ab[name]
            print(f"  draft {name:8s} accept={info['acceptance_rate']:.2f} "
                  f"mean_prefix={info['mean_accepted_prefix_len']:.2f} "
                  f"verify_steps={info['verify_steps']} "
                  f"emitted={info['emitted_tokens']} "
                  f"token_exact={info['token_exact']} "
                  f"extra_plane_bytes={info['extra_plane_nbytes']}")

    kv_ab = None
    if not args.skip_kv_ab:
        print("== paged-KV A/B (per-bits replays + prefix cache) ==")
        kv_ab = run_kv_ab(params, cfg, args)
        for b, info in kv_ab["per_bits"].items():
            kvs = info["kv"]
            print(f"  kv_bits {b:5s} bytes/token="
                  f"{kvs.get('bytes_per_token', 0):6d} "
                  f"tok/s={info['throughput_tok_s']:.1f} "
                  f"exact_vs_dense={info['token_exact_vs_dense']}")
        print(f"  KV bytes staircase strictly decreasing: "
              f"{kv_ab['kv_bytes_strictly_decreasing']}; "
              f"fp token-exact: {kv_ab['fp_token_exact']}")
        on, off = kv_ab["prefix_ab"]["on"], kv_ab["prefix_ab"]["off"]
        print(f"  prefix cache: hit_rate={on['prefix_hit_rate']:.2f} "
              f"ttft_hit={on['mean_ttft_hit_s']:.3f}s "
              f"ttft_cold={on['mean_ttft_cold_s']:.3f}s "
              f"(off: ttft={off['mean_ttft_s']:.3f}s)")

    attn_kernel_ab = None
    if not args.skip_attn_ab:
        print("== fused-vs-gather paged-attention A/B ==")
        attn_kernel_ab = run_attn_kernel_ab(params, cfg, args)
        for b, info in attn_kernel_ab["per_bits"].items():
            print(f"  kv_bits {b:5s} "
                  f"read_bytes/token={info['kv_read_bytes_per_token']:6d} "
                  f"fused_tok/s={info['fused']['throughput_tok_s']:.1f} "
                  f"gather_tok/s={info['gather']['throughput_tok_s']:.1f} "
                  f"token_exact={info['token_exact_vs_gather']}")
        print(f"  KV read-bytes staircase strictly decreasing: "
              f"{attn_kernel_ab['kv_read_bytes_strictly_decreasing']}; "
              f"token-exact at all widths: "
              f"{attn_kernel_ab['token_exact_all_widths']}")

    packed_ab_tp = None
    if not args.skip_packed_ab and args.tp_model_parallel:
        print(f"== TP-sharded per-tier packed replays "
              f"({args.tp_devices}-device host mesh, "
              f"model_parallel={list(args.tp_model_parallel)}) ==")
        packed_ab_tp = run_tp_ab(args)
        for mp_key, frag in packed_ab_tp.items():
            mp = frag["model_parallel"]
            for name, info in frag["per_tier"].items():
                print(f"  {mp_key} tier {name:16s} "
                      f"packed_nbytes={info['packed_nbytes']:,d} "
                      f"per_device={info['per_device_plane_nbytes']:,d} "
                      f"tok/s={info['throughput_tok_s']:.1f}")
            print(f"  {mp_key}: per-device == total/{mp}: "
                  f"{frag['per_device_equals_total_over_mp']}; per-device "
                  f"staircase strictly decreasing: "
                  f"{frag['per_device_plane_bytes_strictly_decreasing']}")

    fleet_ab = None
    if not args.skip_fleet_ab and args.fleet_replicas:
        print(f"== replica-fleet A/B ({args.fleet_devices}-device host "
              f"mesh, replicas={list(args.fleet_replicas)}) ==")
        fleet_ab = run_fleet_ab(args)
        for key, info in fleet_ab["throughput"].items():
            print(f"  {key}: tok/s={info['throughput_tok_s']:.1f} "
                  f"lost={info['requests_lost']} "
                  f"token_exact={info.get('token_exact_vs_single_replica')}")
        spike = fleet_ab["load_spike"]
        print(f"  spike: downgraded {spike['downgraded_replicas']}/"
              f"{spike['replicas']} replicas "
              f"(per_replica_downgrade={spike['per_replica_downgrade']})")
        kill = fleet_ab["kill_one_replica"]
        print(f"  kill-one: lost={kill['requests_lost']} "
              f"requeued={kill['requeued_requests']} "
              f"token_exact={kill.get('token_exact_vs_single_replica')}")

    report = {
        "bench": "serve_throughput",
        "arch": args.arch + (" (reduced)" if args.reduced else ""),
        "requests": args.requests,
        "prompt_len": args.prompt_len,
        "gen_tokens": args.gen_tokens,
        "arrival_rate_per_s": args.arrival_rate,
        "num_slots": args.num_slots,
        "elastic": elastic,
        "fixed_int8": fixed,
        "packed_ab": packed_ab,
        "packed_ab_moe": packed_ab_moe,
        "packed_ab_ep": packed_ab_ep,
        "specdecode_ab": specdecode_ab,
        "kv_ab": kv_ab,
        "attn_kernel_ab": attn_kernel_ab,
        "packed_ab_tp": packed_ab_tp,
        "fleet_ab": fleet_ab,
        # per-section closure trace counts, each verified by
        # compile_guard.assert_no_recompiles (docs/contracts.md) -- a
        # diff here is a compile-count regression
        "compile_counts": dict(COMPILE_COUNTS),
        # headline numbers (the acceptance-criterion fields)
        "throughput_tok_s": elastic["throughput_tok_s"],
        "mean_ttft_s": elastic["mean_ttft_s"],
        "tier_occupancy": elastic["tier_occupancy"],
    }
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {out}")
    return report


if __name__ == "__main__":
    main()
