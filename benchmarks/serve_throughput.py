"""Continuous-batching serving benchmark -> BENCH_serve.json.

Replays a Poisson arrival trace through the elastic-precision
continuous-batching scheduler and records throughput (tok/s), mean
TTFT, queue behavior, and per-tier occupancy -- the serving-side
counterpart of the paper-table quality benchmarks, so each PR's
scheduler changes show up as numbers.

Two runs are reported side by side on the SAME trace:

  * elastic  -- router downgrades int8 -> int4 -> Mix'n'Match -> int2
    as the queue builds, recovers as it drains;
  * fixed    -- int8 only (the quality-maximal baseline).

  PYTHONPATH=src python benchmarks/serve_throughput.py --reduced
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.serve import Engine, Request, ServeConfig
from repro.serve.scheduler import poisson_trace


def run_once(engine, cfg, args, *, elastic: bool):
    sched = engine.scheduler(elastic=elastic,
                             thresholds=args.thresholds, cooldown=args.cooldown)
    trace = poisson_trace(cfg, requests=args.requests,
                          prompt_len=args.prompt_len,
                          gen_tokens=args.gen_tokens,
                          rate=args.arrival_rate, seed=args.seed)
    # warm the jitted prefill/decode closures (and, for elastic, the
    # tier materializations) so the replay measures steady-state serving
    for tier_warm in range(4 if elastic else 1):
        if elastic:
            sched.router.index = tier_warm
            sched.tier = sched.router.tier
            sched.params = sched.tier_cache.get(sched.tier)
        sched.submit(Request(uid=f"_warm{tier_warm}",
                             prompt=trace[0][1].prompt,
                             max_new_tokens=2))
        sched.run_until_idle()
    sched.reset()
    t0 = time.perf_counter()
    results = sched.run_trace(trace)
    wall = time.perf_counter() - t0
    assert len(results) == args.requests, (len(results), args.requests)
    summary = sched.metrics.summary()
    summary["wall_s"] = wall
    return summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family model (CPU-sized)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-tokens", type=int, default=12)
    ap.add_argument("--arrival-rate", type=float, default=1000.0)
    ap.add_argument("--num-slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--thresholds", type=float, nargs="*", default=(2, 6, 12))
    ap.add_argument("--cooldown", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = api.init(jax.random.PRNGKey(args.seed), cfg)
    engine = Engine(params, cfg, ServeConfig(
        bits=8, max_len=args.prompt_len + args.gen_tokens,
        num_slots=args.num_slots, page_size=args.page_size))

    print(f"== elastic tiers, {args.requests} Poisson arrivals "
          f"@ {args.arrival_rate}/s ==")
    elastic = run_once(engine, cfg, args, elastic=True)
    print(json.dumps(elastic, indent=2))
    print("== fixed int8, same trace ==")
    fixed = run_once(engine, cfg, args, elastic=False)
    print(json.dumps(fixed, indent=2))

    report = {
        "bench": "serve_throughput",
        "arch": args.arch + (" (reduced)" if args.reduced else ""),
        "requests": args.requests,
        "prompt_len": args.prompt_len,
        "gen_tokens": args.gen_tokens,
        "arrival_rate_per_s": args.arrival_rate,
        "num_slots": args.num_slots,
        "elastic": elastic,
        "fixed_int8": fixed,
        # headline numbers (the acceptance-criterion fields)
        "throughput_tok_s": elastic["throughput_tok_s"],
        "mean_ttft_s": elastic["mean_ttft_s"],
        "tier_occupancy": elastic["tier_occupancy"],
    }
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {out}")
    return report


if __name__ == "__main__":
    main()
