"""Continuous-batching serving benchmark -> BENCH_serve.json.

Replays a Poisson arrival trace through the elastic-precision
continuous-batching scheduler and records throughput (tok/s), mean
TTFT, queue behavior, and per-tier occupancy -- the serving-side
counterpart of the paper-table quality benchmarks, so each PR's
scheduler changes show up as numbers.

Runs reported side by side on the SAME trace:

  * elastic        -- router downgrades int8 -> int4 -> Mix'n'Match ->
    int2 as the queue builds, recovers as it drains (dequantized tiers);
  * fixed          -- int8 only (the quality-maximal baseline);
  * packed A/B     -- the same elastic replay twice, once over PACKED
    r-bit tier planes and once over dequantized tiers, with measured
    per-tier HBM weight bytes (`packed_nbytes`, shrinking per downgrade
    step with the per-layer bit sum: int8 -> int4 -> Mix'n'Match ~3.3 ->
    int2, every tier packed incl. the per-layer MnM planes) and tok/s --
    the paper's Section 5.4 bytes claim as a reported number instead of
    an assertion;
  * MoE packed A/B -- the same packed-vs-dequant elastic replay on a
    granite_moe config (expert stacks served as per-expert packed
    planes), so the bytes claim also covers the MoE layout
    (`packed_ab_moe` in BENCH_serve.json).

  PYTHONPATH=src python benchmarks/serve_throughput.py --reduced
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.configs import get_config
from repro.models import api
from repro.serve import Engine, Request, ServeConfig
from repro.serve.scheduler import poisson_trace


def tier_bytes(sched) -> dict:
    """Measured per-tier weight footprint from the scheduler's cache."""
    out = {}
    for tier in sched.router.tiers:
        e = sched.tier_cache.get(tier)
        out[tier.name] = {"packed_bits": e.packed_bits,
                          "packed_nbytes": e.packed_nbytes,
                          "weight_nbytes": e.weight_nbytes}
    return out


def run_once(engine, cfg, args, *, elastic: bool, packed: bool | None = None):
    sched = engine.scheduler(elastic=elastic, thresholds=args.thresholds,
                             cooldown=args.cooldown, packed=packed)
    trace = poisson_trace(cfg, requests=args.requests,
                          prompt_len=args.prompt_len,
                          gen_tokens=args.gen_tokens,
                          rate=args.arrival_rate, seed=args.seed)
    # warm the jitted prefill/decode closures (one per packed bitwidth
    # for packed tiers; one prefill trace per admission-burst row
    # bucket) and the tier materializations so the replay measures
    # steady-state serving. Row buckets are powers of two up to AND
    # covering num_slots (a 5-admission burst on 6 slots pads to 8
    # rows, so that shape needs warming too).
    row_buckets = [1]
    while row_buckets[-1] < args.num_slots:
        row_buckets.append(row_buckets[-1] * 2)
    if elastic:
        # pin the router: warm bursts would otherwise raise the load
        # signal and re-route mid-warm, leaving some (bitwidth, rows)
        # closure shapes cold and compiling inside the timed replay
        saved = (sched.router.thresholds, sched.router.cooldown)
        sched.router.thresholds = (float("inf"),) * len(saved[0])
        sched.router.cooldown = 10**9
    for tier_warm in range(len(sched.router.tiers) if elastic else 1):
        if elastic:
            sched.router.index = tier_warm
            sched._set_tier(sched.router.tier)
        for rows in row_buckets:
            for j in range(min(rows, args.num_slots)):
                sched.submit(Request(uid=f"_warm{tier_warm}_{rows}_{j}",
                                     prompt=trace[0][1].prompt,
                                     max_new_tokens=2))
            sched.run_until_idle()
    if elastic:
        sched.router.thresholds, sched.router.cooldown = saved
    sched.reset()
    t0 = time.perf_counter()
    results = sched.run_trace(trace)
    wall = time.perf_counter() - t0
    assert len(results) == args.requests, (len(results), args.requests)
    summary = sched.metrics.summary()
    summary["wall_s"] = wall
    summary["prefill_calls"] = sched.prefill_calls
    per_tier = tier_bytes(sched) if elastic else None
    return summary, per_tier


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family model (CPU-sized)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-tokens", type=int, default=12)
    ap.add_argument("--arrival-rate", type=float, default=1000.0)
    ap.add_argument("--num-slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--thresholds", type=float, nargs="*", default=(2, 6, 12))
    ap.add_argument("--cooldown", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-packed-ab", action="store_true",
                    help="skip the packed-vs-dequant elastic A/B replay")
    ap.add_argument("--moe-arch", default="granite_moe_1b_a400m",
                    help="MoE config for the second packed A/B "
                         "('none' skips it)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = api.init(jax.random.PRNGKey(args.seed), cfg)
    engine = Engine(params, cfg, ServeConfig(
        bits=8, max_len=args.prompt_len + args.gen_tokens,
        num_slots=args.num_slots, page_size=args.page_size))

    print(f"== elastic tiers, {args.requests} Poisson arrivals "
          f"@ {args.arrival_rate}/s ==")
    elastic, elastic_tiers = run_once(engine, cfg, args, elastic=True)
    print(json.dumps(elastic, indent=2))
    print("== fixed int8, same trace ==")
    fixed, _ = run_once(engine, cfg, args, elastic=False)
    print(json.dumps(fixed, indent=2))

    def _print_tiers(tiers):
        for name, info in tiers.items():
            print(f"  tier {name:16s} packed_bits={info['packed_bits']} "
                  f"packed_nbytes={info['packed_nbytes']:,d} "
                  f"weight_nbytes={info['weight_nbytes']:,d}")

    packed_ab = None
    if not args.skip_packed_ab:
        print("== packed-vs-dequant elastic A/B, same trace ==")
        packed, packed_tiers = run_once(engine, cfg, args, elastic=True,
                                        packed=True)
        packed_ab = {
            "packed": {"summary": packed, "per_tier": packed_tiers,
                       "throughput_tok_s": packed["throughput_tok_s"]},
            "dequant": {"summary": elastic, "per_tier": elastic_tiers,
                        "throughput_tok_s": elastic["throughput_tok_s"]},
        }
        _print_tiers(packed_tiers)

    packed_ab_moe = None
    if not args.skip_packed_ab and args.moe_arch != "none":
        # the same packed-vs-dequant A/B on a MoE config: expert stacks
        # serve as per-expert packed planes, Mix'n'Match as per-layer
        # planes, so a downgrade moves weight bytes on every layout
        print(f"== MoE packed-vs-dequant elastic A/B ({args.moe_arch}) ==")
        cfg_moe = get_config(args.moe_arch)
        if args.reduced:
            cfg_moe = cfg_moe.reduced()
        params_moe = api.init(jax.random.PRNGKey(args.seed), cfg_moe)
        engine_moe = Engine(params_moe, cfg_moe, ServeConfig(
            bits=8, max_len=args.prompt_len + args.gen_tokens,
            num_slots=args.num_slots, page_size=args.page_size))
        moe_packed, moe_packed_tiers = run_once(
            engine_moe, cfg_moe, args, elastic=True, packed=True)
        moe_dequant, moe_dequant_tiers = run_once(
            engine_moe, cfg_moe, args, elastic=True, packed=False)
        packed_ab_moe = {
            "arch": args.moe_arch + (" (reduced)" if args.reduced else ""),
            "packed": {"summary": moe_packed, "per_tier": moe_packed_tiers,
                       "throughput_tok_s": moe_packed["throughput_tok_s"]},
            "dequant": {"summary": moe_dequant,
                        "per_tier": moe_dequant_tiers,
                        "throughput_tok_s": moe_dequant["throughput_tok_s"]},
        }
        _print_tiers(moe_packed_tiers)

    report = {
        "bench": "serve_throughput",
        "arch": args.arch + (" (reduced)" if args.reduced else ""),
        "requests": args.requests,
        "prompt_len": args.prompt_len,
        "gen_tokens": args.gen_tokens,
        "arrival_rate_per_s": args.arrival_rate,
        "num_slots": args.num_slots,
        "elastic": elastic,
        "fixed_int8": fixed,
        "packed_ab": packed_ab,
        "packed_ab_moe": packed_ab_moe,
        # headline numbers (the acceptance-criterion fields)
        "throughput_tok_s": elastic["throughput_tok_s"],
        "mean_ttft_s": elastic["mean_ttft_s"],
        "tier_occupancy": elastic["tier_occupancy"],
    }
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {out}")
    return report


if __name__ == "__main__":
    main()
