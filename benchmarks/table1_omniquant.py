"""Table 1: MatQuant with OmniQuant vs per-precision baselines vs sliced
int8, across int8/6/4/3/2 (int6/int3 interpolated, never trained)."""

from repro.core.quant import QuantConfig

from benchmarks.common import calibrate_omniquant, eval_nll


def run():
    mat_q = QuantConfig(mode="omniquant", bitwidths=(8, 4, 2),
                        weights=(0.1, 0.1, 1.0))
    mat, cfg_m = calibrate_omniquant(mat_q)
    rows = []
    # per-precision baselines (explicitly calibrated for one bit-width)
    for b in (8, 6, 4, 3, 2):
        base_q = QuantConfig(mode="omniquant", bitwidths=(b,), weights=(1.0,))
        base, cfg_b = calibrate_omniquant(base_q)
        nll_b, us = eval_nll(base, cfg_b, b)
        rows.append((f"table1/omniquant/int{b}/baseline", us, nll_b))
        nll_m, us = eval_nll(mat, cfg_m, b)
        rows.append((f"table1/omniquant/int{b}/matquant", us, nll_m))
    # sliced int8 baseline (no matquant training) at lower precisions
    base8, cfg8 = calibrate_omniquant(
        QuantConfig(mode="omniquant", bitwidths=(8,), weights=(1.0,)))
    for b in (4, 2):
        nll_s, us = eval_nll(base8, cfg8, b)
        rows.append((f"table1/omniquant/int{b}/sliced_int8", us, nll_s))
    return rows
