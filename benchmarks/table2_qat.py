"""Table 2: MatQuant with QAT vs per-precision QAT baselines vs sliced."""

from repro.core.quant import QuantConfig

from benchmarks.common import eval_nll, train_qat


def run():
    mat_q = QuantConfig(mode="qat", bitwidths=(8, 4, 2), weights=(0.1, 0.1, 1.0))
    mat, cfg_m = train_qat(mat_q, tag="t2mat")
    base8, cfg8 = train_qat(QuantConfig(mode="qat", bitwidths=(8,),
                                        weights=(1.0,)), tag="t2b8")
    rows = []
    for b in (8, 6, 4, 3, 2):
        base_q = QuantConfig(mode="qat", bitwidths=(b,), weights=(1.0,))
        base, cfg_b = train_qat(base_q, tag=f"t2b{b}")
        nll_b, us = eval_nll(base, cfg_b, b)
        rows.append((f"table2/qat/int{b}/baseline", us, nll_b))
        nll_m, us = eval_nll(mat, cfg_m, b)
        rows.append((f"table2/qat/int{b}/matquant", us, nll_m))
        nll_s, us = eval_nll(base8, cfg8, b)
        rows.append((f"table2/qat/int{b}/sliced_int8", us, nll_s))
    return rows
