"""Table 3: loss re-weighting (lambda_8, lambda_4, lambda_2) ablation."""

from repro.core.quant import QuantConfig

from benchmarks.common import eval_nll, train_qat

WEIGHTINGS = [(0.1, 0.1, 1.0), (0.3, 0.3, 1.0), (0.5, 0.5, 1.0)]


def run():
    rows = []
    for w in WEIGHTINGS:
        q = QuantConfig(mode="qat", bitwidths=(8, 4, 2), weights=w)
        params, cfg = train_qat(q, tag=f"t3w{w}")
        for b in (8, 4, 2):
            nll, us = eval_nll(params, cfg, b)
            tag = f"{w[0]:g}_{w[1]:g}_{w[2]:g}"
            rows.append((f"table3/weights_{tag}/int{b}", us, nll))
    return rows
