"""Table 4: co-distillation ablation -- [8,4,2], [8,4,8->2], [8,4,2,8->2]."""

from repro.core.quant import QuantConfig

from benchmarks.common import eval_nll, train_qat

CONFIGS = {
    "8_4_2": QuantConfig(mode="qat", bitwidths=(8, 4, 2),
                         weights=(0.1, 0.1, 1.0)),
    "8_4_8to2": QuantConfig(mode="qat", bitwidths=(8, 4),
                            weights=(0.1, 0.1), codistill=((8, 2),)),
    "8_4_2_8to2": QuantConfig(mode="qat", bitwidths=(8, 4, 2),
                              weights=(0.1, 0.1, 1.0), codistill=((8, 2),)),
}


def run():
    rows = []
    for name, q in CONFIGS.items():
        params, cfg = train_qat(q, tag=f"t4{name}")
        for b in (8, 4, 2):
            nll, us = eval_nll(params, cfg, b)
            rows.append((f"table4/{name}/int{b}", us, nll))
    return rows
