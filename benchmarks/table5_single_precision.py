"""Table 5: Single-Precision MatQuant (R={2}, int8 parent) vs MatQuant
vs explicitly-int2 baseline."""

from repro.core.quant import QuantConfig

from benchmarks.common import eval_nll, train_qat


def run():
    rows = []
    sp, cfg_sp = train_qat(QuantConfig(mode="qat", bitwidths=(2,),
                                       weights=(1.0,), parent_bits=8),
                           tag="t5sp")
    mat, cfg_m = train_qat(QuantConfig(mode="qat", bitwidths=(8, 4, 2),
                                       weights=(0.1, 0.1, 1.0)), tag="t2mat")
    base2, cfg_b = train_qat(QuantConfig(mode="qat", bitwidths=(2,),
                                         weights=(1.0,), parent_bits=2),
                             tag="t5b2")
    nll, us = eval_nll(sp, cfg_sp, 2)
    rows.append(("table5/int2/sp_matquant", us, nll))
    nll, us = eval_nll(mat, cfg_m, 2)
    rows.append(("table5/int2/matquant", us, nll))
    nll, us = eval_nll(base2, cfg_b, 2)
    rows.append(("table5/int2/baseline", us, nll))
    # Tables 23/24: the S.P. parent evaluated at int8/int4 (sliced post hoc)
    for b in (8, 4):
        nll, us = eval_nll(sp, cfg_sp, b)
        rows.append((f"table5/int{b}/sp_matquant_sliced", us, nll))
    return rows
