"""Table 6: FFN + Attention quantization with QAT (scope='ffn+attn');
the paper finds baseline int2 destabilizes while MatQuant trains."""

from repro.core.quant import QuantConfig

from benchmarks.common import eval_nll, train_qat


def run():
    rows = []
    mat, cfg_m = train_qat(
        QuantConfig(mode="qat", bitwidths=(8, 4, 2), weights=(0.1, 0.1, 1.0),
                    scope="ffn+attn"), tag="t6mat")
    base2, cfg_b = train_qat(
        QuantConfig(mode="qat", bitwidths=(2,), weights=(1.0,),
                    parent_bits=2, scope="ffn+attn"), tag="t6b2")
    sp, cfg_sp = train_qat(
        QuantConfig(mode="qat", bitwidths=(2,), weights=(1.0,),
                    parent_bits=8, scope="ffn+attn"), tag="t6sp")
    for b in (8, 4, 2):
        nll, us = eval_nll(mat, cfg_m, b)
        rows.append((f"table6/ffn_attn/int{b}/matquant", us, nll))
    nll, us = eval_nll(base2, cfg_b, 2)
    rows.append(("table6/ffn_attn/int2/baseline", us, nll))
    nll, us = eval_nll(sp, cfg_sp, 2)
    rows.append(("table6/ffn_attn/int2/sp_matquant", us, nll))
    return rows
