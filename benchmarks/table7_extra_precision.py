"""Table 7: Extra-Precision MatQuant (Errata Eq. 8 overflow bucket) vs
MatQuant; also reports the measured effective bits (~2.05 for int2)."""

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.quant import QuantConfig

from benchmarks.common import eval_nll, train_qat


def _avg_effective_bits(params, cfg, r):
    vals = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        names = [str(getattr(k, "key", "")) for k in path]
        if names[-1:] == ["w"] and "ffn" in names:
            q, _, _ = quant.quantize(leaf.astype(jnp.float32), 8,
                                     axis=1 if leaf.ndim == 3 else 0)
            vals.append(float(quant.effective_bits(q, 8, r)))
    return sum(vals) / max(len(vals), 1)


def run():
    mat, cfg_m = train_qat(QuantConfig(mode="qat", bitwidths=(8, 4, 2),
                                       weights=(0.1, 0.1, 1.0)), tag="t2mat")
    ep, cfg_e = train_qat(QuantConfig(mode="qat", bitwidths=(8, 4, 2),
                                      weights=(1.0, 1.0, 1.0),
                                      extra_precision=True), tag="t7ep")
    rows = []
    for b in (8, 4, 2):
        nll_m, us = eval_nll(mat, cfg_m, b)
        rows.append((f"table7/int{b}/matquant", us, nll_m))
        nll_e, us = eval_nll(ep, cfg_e, b)
        rows.append((f"table7/int{b}/ep_matquant", us, nll_e))
        eff = _avg_effective_bits(ep, cfg_e, b)
        rows.append((f"table7/int{b}/ep_effective_bits", 0.0, eff))
    return rows
