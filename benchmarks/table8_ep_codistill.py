"""Table 8: co-distillation within Extra-Precision MatQuant."""

from repro.core.quant import QuantConfig

from benchmarks.common import eval_nll, train_qat


def run():
    rows = []
    for name, codistill in [("8_4_2", ()), ("8_4_2_8to2", ((8, 2),))]:
        q = QuantConfig(mode="qat", bitwidths=(8, 4, 2),
                        weights=(1.0, 1.0, 1.0), extra_precision=True,
                        codistill=codistill)
        params, cfg = train_qat(q, tag=f"t8{name}")
        for b in (8, 4, 2):
            nll, us = eval_nll(params, cfg, b)
            rows.append((f"table8/ep_{name}/int{b}", us, nll))
    return rows
