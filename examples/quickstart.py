"""Quickstart: MatQuant in ~60 lines.

Trains a tiny LM with the paper's multi-precision objective (R={8,4,2}),
then shows the Matryoshka property: int8/int6/int4/int3/int2 models all
sliced out of the SAME weights, plus a Mix'n'Match assignment.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import mixnmatch
from repro.core.matquant import cross_entropy
from repro.core.quant import QuantConfig
from repro.data import DataConfig, SyntheticCorpus
from repro.models import api
from repro.optim import OptConfig
from repro.serve import Engine, ServeConfig
from repro.train import init_train_state, make_train_step

STEPS, BATCH, SEQ = 60, 8, 64

# 1. a tiny Qwen3-family model with MatQuant QAT on the FFN weights
cfg = get_config("qwen3_1_7b").reduced().replace(
    quant=QuantConfig(mode="qat", bitwidths=(8, 4, 2), weights=(0.1, 0.1, 1.0)))
opt = OptConfig(lr=3e-3, total_steps=STEPS, warmup_steps=5)
params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
train_step = jax.jit(make_train_step(cfg, opt))

corpus = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size, seq_len=SEQ))
print("training with joint int8+int4+int2 loss ...")
for i in range(STEPS):
    raw = corpus.batch(i, BATCH, SEQ)
    batch = {k: jnp.asarray(v) for k, v in raw.items()}
    params, opt_state, m = train_step(params, opt_state, batch)
    if i % 20 == 0 or i == STEPS - 1:
        print(f"  step {i:3d}  loss={float(m['loss']):.3f} "
              f"int8={float(m['ce_int8']):.3f} int2={float(m['ce_int2']):.3f}")

# 2. ONE set of weights, five serving precisions (int6/int3 interpolated)
held = corpus.batch(10_000, 16, SEQ)
toks, labels = jnp.asarray(held["tokens"]), jnp.asarray(held["labels"])
print("\nnested precisions sliced from the same int8 parent:")
for bits in (8, 6, 4, 3, 2):
    logits, _ = api.forward(params, {"tokens": toks}, cfg, bits=bits)
    print(f"  int{bits}: log pplx = {float(cross_entropy(logits, labels)):.3f}")

# 3. layer-wise Mix'n'Match at a 5.0-bit budget (pyramid strategy)
assignment = mixnmatch.assign(cfg.num_layers, 5.0, "pyramid")
logits, _ = api.forward(params, {"tokens": toks}, cfg, bits=assignment)
print(f"\nmix'n'match {assignment} "
      f"({mixnmatch.effective_bits(assignment):.2f} eff bits): "
      f"log pplx = {float(cross_entropy(logits, labels)):.3f}")

# 4. deployment: materialize served weights and generate
engine = Engine(params, cfg, ServeConfig(bits=2, max_len=SEQ + 8))
gen = engine.generate(toks[:2, :16], 8)
print(f"\nint2-served greedy continuations: {gen.tolist()}")
