"""Serving example: one checkpoint, every precision (Section 5.4).

Slices a single int8 parent to uniform int8/4/2, interpolated int6/int3,
Mix'n'Match budgets, and Extra-Precision int2 (~2.05 bits), serving a
batch of requests at each and reporting quality + packed HBM footprint.

  PYTHONPATH=src python examples/serve_elastic_precision.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import mixnmatch, packing
from repro.core.quant import QuantConfig
from repro.data import DataConfig, SyntheticCorpus
from repro.optim import OptConfig
from repro.serve import Engine, ServeConfig
from repro.train import init_train_state, make_train_step

# train a small MatQuant model to serve
cfg = get_config("gemma2_2b").reduced().replace(
    quant=QuantConfig(mode="qat", bitwidths=(8, 4, 2), weights=(0.1, 0.1, 1.0)))
opt = OptConfig(lr=3e-3, total_steps=60, warmup_steps=5)
params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
step = jax.jit(make_train_step(cfg, opt))
corpus = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size, seq_len=64))
for i in range(60):
    b = corpus.batch(i, 8, 64)
    params, opt_state, _ = step(params, opt_state,
                                {k: jnp.asarray(v) for k, v in b.items()})

held = corpus.batch(10_000, 16, 64)
toks, labels = jnp.asarray(held["tokens"]), jnp.asarray(held["labels"])

d_in, d_out = cfg.d_model, cfg.d_ff
print(f"{'serving config':28s} {'eff bits':>8s} {'log pplx':>9s} "
      f"{'FFN-up HBM bytes':>17s}")
for name, bits, eff in [
    ("uniform int8", 8, 8.0),
    ("interpolated int6", 6, 6.0),
    ("uniform int4", 4, 4.0),
    ("interpolated int3", 3, 3.0),
    ("uniform int2", 2, 2.0),
    ("mix'n'match 3.0-bit", mixnmatch.assign(cfg.num_layers, 3.0), 3.0),
    ("mix'n'match 5.0-bit", mixnmatch.assign(cfg.num_layers, 5.0), 5.0),
]:
    eng = Engine(params, cfg, ServeConfig(bits=bits, max_len=96))
    nll = eng.score(toks, labels)
    b0 = bits if isinstance(bits, int) else min(bits)
    b_pack = next(w for w in (1, 2, 4, 8) if w >= b0)  # storage width
    nbytes = packing.packed_nbytes(d_in, d_out, b_pack)
    print(f"{name:28s} {eff:8.2f} {nll:9.3f} {nbytes:17,d}")

# Extra-Precision int2: the overflow bucket at ~0.05 extra bits
# (served packed, the 1-bit bitmap rides the plane into the kernel;
# stored cost is 2 + 1 bitmap bits/weight)
eng_ep = Engine(params, cfg, ServeConfig(bits=2, max_len=96,
                                         extra_precision=True))
nbytes_ep = packing.packed_nbytes(d_in, d_out, 2, extra_precision=True)
print(f"{'extra-precision int2':28s} {'~2.05':>8s} "
      f"{eng_ep.score(toks, labels):9.3f} {nbytes_ep:17,d}")

gen = eng_ep.generate(toks[:2, :16], 8)
print("\nEP-int2 greedy continuations:", gen.tolist())
