"""Serving example: one checkpoint, every precision (Section 5.4).

Slices a single int8 parent to uniform int8/4/2, interpolated int6/int3,
Mix'n'Match budgets, and Extra-Precision int2 (~2.05 bits), serving a
batch of requests at each and reporting quality + packed HBM footprint.

`--model-parallel N` serves on a `(data, model)` host mesh instead: the
engine places every served tier with NamedShardings (packed planes
shard over 'model', KV slots over 'data') and the FFN-up bytes column
becomes the PER-DEVICE staircase -- total / N at every tier. The
default N=1 runs the same mesh code degenerately on one device; for a
real TP split on a CPU-only host, force devices first, e.g.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
      python examples/serve_elastic_precision.py --model-parallel 2

  PYTHONPATH=src python examples/serve_elastic_precision.py
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import mixnmatch, packing
from repro.core.quant import QuantConfig
from repro.data import DataConfig, SyntheticCorpus
from repro.launch.mesh import make_host_mesh
from repro.optim import OptConfig
from repro.runtime.sharding import mesh_axis_sizes
from repro.serve import Engine, ServeConfig
from repro.train import init_train_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--model-parallel", type=int, default=1,
                help="model-parallel degree of the (data, model) host mesh "
                     "every tier is served on; must divide the local device "
                     "count (XLA_FLAGS=--xla_force_host_platform_device_"
                     "count=N forces CPU devices). 1 = degenerate mesh, "
                     "same code path, per-device bytes == total")
ap.add_argument("--spec-decode", action="store_true",
                help="demo Matryoshka self-speculative decoding: each draft "
                     "rung (int4 / int2+ep / int2) drafts against the int8 "
                     "verify tier, printing acceptance rate, mean accepted "
                     "prefix, and verify steps per token -- output is "
                     "token-identical to plain int8 decode at every rung")
ap.add_argument("--draft-len", type=int, default=4,
                help="k, tokens drafted per verify step (--spec-decode)")
args = ap.parse_args()
mp = args.model_parallel
mesh = make_host_mesh(mp)
print(f"serving mesh: {mesh_axis_sizes(mesh)}\n")

# train a small MatQuant model to serve
cfg = get_config("gemma2_2b").reduced().replace(
    quant=QuantConfig(mode="qat", bitwidths=(8, 4, 2), weights=(0.1, 0.1, 1.0)))
opt = OptConfig(lr=3e-3, total_steps=60, warmup_steps=5)
params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
step = jax.jit(make_train_step(cfg, opt))
corpus = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size, seq_len=64))
for i in range(60):
    b = corpus.batch(i, 8, 64)
    params, opt_state, _ = step(params, opt_state,
                                {k: jnp.asarray(v) for k, v in b.items()})

held = corpus.batch(10_000, 16, 64)
toks, labels = jnp.asarray(held["tokens"]), jnp.asarray(held["labels"])

d_in, d_out = cfg.d_model, cfg.d_ff
print(f"{'serving config':28s} {'eff bits':>8s} {'log pplx':>9s} "
      f"{'FFN-up HBM B/device':>20s}")
for name, bits, eff in [
    ("uniform int8", 8, 8.0),
    ("interpolated int6", 6, 6.0),
    ("uniform int4", 4, 4.0),
    ("interpolated int3", 3, 3.0),
    ("uniform int2", 2, 2.0),
    ("mix'n'match 3.0-bit", mixnmatch.assign(cfg.num_layers, 3.0), 3.0),
    ("mix'n'match 5.0-bit", mixnmatch.assign(cfg.num_layers, 5.0), 5.0),
]:
    eng = Engine(params, cfg, ServeConfig(bits=bits, max_len=96), mesh=mesh)
    nll = eng.score(toks, labels)
    b0 = bits if isinstance(bits, int) else min(bits)
    b_pack = next(w for w in (1, 2, 4, 8) if w >= b0)  # storage width
    nbytes = packing.packed_nbytes(d_in, d_out, b_pack, model_parallel=mp)
    print(f"{name:28s} {eff:8.2f} {nll:9.3f} {nbytes:20,d}")

# Extra-Precision int2: the overflow bucket at ~0.05 extra bits
# (served packed, the 1-bit bitmap rides the plane into the kernel;
# stored cost is 2 + 1 bitmap bits/weight)
eng_ep = Engine(params, cfg, ServeConfig(bits=2, max_len=96,
                                         extra_precision=True), mesh=mesh)
nbytes_ep = packing.packed_nbytes(d_in, d_out, 2, extra_precision=True,
                                  model_parallel=mp)
print(f"{'extra-precision int2':28s} {'~2.05':>8s} "
      f"{eng_ep.score(toks, labels):9.3f} {nbytes_ep:20,d}")
if mp > 1:
    total = packing.packed_nbytes(d_in, d_out, 2, extra_precision=True)
    print(f"\nper-device bytes are total/{mp} at every tier "
          f"(e.g. ep-int2: {total:,d} -> {nbytes_ep:,d})")

gen = eng_ep.generate(toks[:2, :16], 8)
print("\nEP-int2 greedy continuations:", gen.tolist())

if args.spec_decode:
    # self-speculative decoding: the draft rungs alias the int8 verify
    # tier's parent, so each row below is a FREE draft model -- output
    # stays token-identical to plain int8 decode, only the verify-step
    # count changes
    from repro.serve import SpecDecodeConfig
    eng8 = Engine(params, cfg, ServeConfig(bits=8, max_len=96, num_slots=4),
                  mesh=mesh)
    prompts, n_new = toks[:4, :16], 24
    plain = eng8.generate(prompts, n_new)
    print(f"\nself-speculative decoding (int8 verify, k={args.draft_len}):")
    print(f"{'draft rung':16s} {'accept rate':>11s} {'mean prefix':>11s} "
          f"{'verify steps/tok':>17s} {'token-exact':>12s}")
    for rung, dbits, ep in [("int4", 4, False), ("int2+ep", 2, True),
                            ("int2", 2, False)]:
        sd = SpecDecodeConfig(draft_bits=dbits, draft_extra_precision=ep,
                              draft_len=args.draft_len)
        out = eng8.generate(prompts, n_new, spec_decode=sd)
        spec = next(iter(eng8._schedulers.values())).metrics.summary()["spec"]
        exact = bool((out == plain).all())
        print(f"{rung:16s} {spec['acceptance_rate']:11.2f} "
              f"{spec['mean_accepted_prefix_len']:11.2f} "
              f"{spec['verify_steps_per_token']:17.2f} {str(exact):>12s}")
