"""End-to-end training driver example: the full production loop
(mesh + sharding + fault tolerance + checkpointing) on a ~10M-param
model for a few hundred steps. Pass --full to use the ~100M-param
config (sized for a real accelerator; it runs on CPU, slowly).

  PYTHONPATH=src python examples/train_matquant_e2e.py
  PYTHONPATH=src python examples/train_matquant_e2e.py --full --steps 300
"""

import argparse
import sys

from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params (accelerator-sized)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/matquant_e2e")
    args = ap.parse_args()

    if args.full:
        # ~100M-param dense model: 12L x d768 x ffn3072, 50k vocab
        import repro.configs.xlstm_125m  # noqa: F401  (same scale class)
        argv = ["--arch", "xlstm_125m", "--steps", str(args.steps),
                "--batch", "16", "--seq", "512"]
    else:
        argv = ["--arch", "qwen3_1_7b", "--reduced", "--steps", str(args.steps),
                "--batch", "8", "--seq", "128"]
    argv += ["--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
             "--bitwidths", "8", "4", "2"]
    print("launching:", " ".join(argv))
    train_driver.main(argv)


if __name__ == "__main__":
    sys.exit(main())
