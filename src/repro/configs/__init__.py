"""Architecture registry: 10 assigned archs + the paper's own models."""
from repro.configs.base import (  # noqa: F401
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    shape_skips,
)
from repro.configs.specs import input_specs  # noqa: F401
