"""Model / shape / run configuration dataclasses and the arch registry.

Every assigned architecture ships as `src/repro/configs/<id>.py` exposing
`CONFIG: ModelConfig` with the exact published dimensions; reduced
smoke-test variants come from `ModelConfig.reduced()`.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Sequence

from repro.core.quant import QuantConfig


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    m_rope: bool = False
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6

    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM / recurrent
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_heads: int = 0
    ssm_conv: int = 4
    attn_period: int = 0              # hybrid: shared attn after every N ssm layers
    block_pattern: str = ""           # 'mamba' | 'mlstm_slstm' | '' (attention)

    # enc-dec
    encoder_layers: int = 0
    encoder_len: int = 1500           # whisper: fixed frame count (stub frontend)

    # numerics / padding
    param_dtype: str = "bfloat16"
    vocab_pad_to: int = 512
    remat: str = "block"              # '' | 'block' | 'dots'
    attn_chunk: int = 1024
    ssm_chunk: int = 128
    unroll_layers: bool = False       # cost-analysis mode (see scan_layers)

    # quantization (the paper's knob set)
    quant: QuantConfig = dataclasses.field(default_factory=QuantConfig)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_to)

    @property
    def resolved_ssm_heads(self) -> int:
        if self.ssm_heads:
            return self.ssm_heads
        return max((self.ssm_expand * self.d_model) // 64, 1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        half = 16  # reduced head_dim 32 -> 16 rotary channels
        w = 3 * half // 8
        return self.replace(
            mrope_sections=(half - 2 * w, w, w),
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            head_dim=32,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=512,
            vocab_pad_to=64,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            # tiny models: no expert capacity drops, so prefill/decode are
            # bit-consistent with the teacher-forced forward in tests
            capacity_factor=8.0 if self.num_experts else self.capacity_factor,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_heads=4 if self.family in ("ssm", "hybrid") and self.block_pattern != "mlstm_slstm" else self.ssm_heads,
            encoder_layers=min(self.encoder_layers, 2) if self.encoder_layers else 0,
            encoder_len=16 if self.encoder_layers else self.encoder_len,
            attn_period=min(self.attn_period, 2) if self.attn_period else 0,
            param_dtype="float32",
            attn_chunk=64,
            ssm_chunk=16,
        )

    def param_count(self) -> int:
        """Analytic parameter count (drives MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.resolved_head_dim
        h, kh = self.num_heads, self.num_kv_heads
        V = self.padded_vocab
        n = V * d  # embedding
        if not self.tie_embeddings:
            n += V * d

        def attn():
            return d * h * hd + 2 * d * kh * hd + h * hd * d

        def dense_ffn(ff):
            return 3 * d * ff

        def moe_ffn():
            return self.num_experts * 3 * d * self.d_ff + d * self.num_experts

        def mamba():
            d_in = self.ssm_expand * d
            return 2 * d * d_in + 2 * d * self.ssm_state + d * self.resolved_ssm_heads + d_in * d

        def mlstm():
            return 4 * d * d  # q,k,v,o

        def slstm():
            dh = d // h
            return 4 * d * d + h * dh * 4 * dh + d * d

        L = self.num_layers
        if self.family in ("dense", "vlm"):
            n += L * (attn() + dense_ffn(self.d_ff))
        elif self.family == "moe":
            n += L * (attn() + moe_ffn())
        elif self.family == "ssm" and self.block_pattern == "mlstm_slstm":
            n += (L // 2 + L % 2) * mlstm() + (L // 2) * slstm()
        elif self.family == "hybrid":
            n += L * mamba() + (attn() + dense_ffn(self.d_ff))  # shared attn block
        elif self.family == "encdec":
            ffn_ungated = 2 * d * self.d_ff  # whisper MLP has no gate
            enc = self.encoder_layers * (attn() + ffn_ungated)
            dec = L * (2 * attn() + ffn_ungated)
            n += enc + dec
        else:
            raise ValueError(self.family)
        # norms are negligible but cheap to add
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k of num_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        full = self.param_count()
        inactive = L * (self.num_experts - self.top_k) * 3 * d * self.d_ff
        return full - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # 'train' | 'prefill' | 'decode'
    microbatches: int = 1


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = (
    "qwen3_1_7b",
    "granite_3_8b",
    "qwen3_8b",
    "qwen3_32b",
    "qwen2_vl_72b",
    "granite_moe_3b_a800m",
    "granite_moe_1b_a400m",
    "xlstm_125m",
    "whisper_small",
    "zamba2_1_2b",
    # the paper's own models
    "gemma2_2b",
    "gemma2_9b",
    "mistral_7b",
)


def get_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def shape_skips(cfg: ModelConfig) -> dict[str, str]:
    """Shape cells skipped for this arch, with reasons (DESIGN.md §4)."""
    skips = {}
    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        skips["long_500k"] = (
            "full quadratic attention; 500k decode requires sub-quadratic "
            "attention (run only for ssm/hybrid archs)"
        )
    return skips
