"""Gemma-2 2B -- one of the paper's own evaluation models."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4,
    head_dim=256, d_ff=9216, vocab_size=256128,
    rope_theta=1e4, tie_embeddings=True,
)
