"""Gemma-2 9B -- one of the paper's own evaluation models."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8,
    head_dim=256, d_ff=14336, vocab_size=256128,
    rope_theta=1e4, tie_embeddings=True,
)
