"""Mistral 7B -- one of the paper's own evaluation models."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=32000,
    rope_theta=1e6, tie_embeddings=False,
)
