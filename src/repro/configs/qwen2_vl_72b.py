"""Qwen2-VL 72B [vlm] -- M-RoPE, dynamic-resolution vision frontend
STUBBED per assignment (input_specs supplies patch embeddings).
[arXiv:2409.12191; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=29568, vocab_size=152064,
    m_rope=True, mrope_sections=(16, 24, 24), rope_theta=1e6,
    tie_embeddings=False,
)
