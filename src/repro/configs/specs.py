"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

`input_specs(cfg, shape)` returns the *batch* pytree the corresponding
step function consumes -- weak-type-correct, shardable, zero allocation.
Params and decode-state specs are derived in the dry-run via
jax.eval_shape over the init functions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

VLM_PATCHES = 1024  # stub: precomputed image patch embeddings per sample


def _s(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {
            "tokens": _s((B, S), jnp.int32),
            "labels": _s((B, S), jnp.int32),
        }
        if cfg.family == "encdec":
            batch["frames"] = _s((B, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["vision_embeds"] = _s((B, VLM_PATCHES, cfg.d_model), jnp.bfloat16)
            batch["positions"] = _s((B, S, 3), jnp.int32)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": _s((B, S), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = _s((B, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["vision_embeds"] = _s((B, VLM_PATCHES, cfg.d_model), jnp.bfloat16)
            batch["positions"] = _s((B, S, 3), jnp.int32)
        return batch
    if shape.kind == "decode":
        return {
            "token": _s((B, 1), jnp.int32),
            "pos": _s((), jnp.int32),
        }
    raise ValueError(shape.kind)


def make_batch(cfg: ModelConfig, shape: ShapeConfig, key=None) -> dict:
    """Concrete random batch matching input_specs (smoke tests/examples)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    specs = input_specs(cfg, shape)
    out = {}
    for name, spec in specs.items():
        key, sub = jax.random.split(key)
        if spec.dtype == jnp.int32 and name in ("tokens", "labels", "token"):
            out[name] = jax.random.randint(sub, spec.shape, 0, cfg.vocab_size, jnp.int32)
        elif name == "pos":
            out[name] = jnp.asarray(0, jnp.int32)
        elif name == "positions":
            pos = jnp.arange(spec.shape[1], dtype=jnp.int32)
            out[name] = jnp.broadcast_to(pos[None, :, None], spec.shape)
        else:
            out[name] = jax.random.normal(sub, spec.shape, jnp.float32).astype(spec.dtype)
    return out
