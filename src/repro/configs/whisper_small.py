"""Whisper small [audio] -- enc-dec backbone; conv frontend STUB
(input_specs supplies precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    num_layers=12, encoder_layers=12, encoder_len=1500,
    d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865, tie_embeddings=True,
)
