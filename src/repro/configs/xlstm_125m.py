"""xLSTM 125M [ssm] -- alternating mLSTM / sLSTM blocks, d_ff=0 (the
blocks carry their own projections). [arXiv:2405.04517; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm", block_pattern="mlstm_slstm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, tie_embeddings=True,
)
