"""Zamba2 1.2B [hybrid] -- Mamba2 backbone + shared attention block
applied every 6 layers. [arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    head_dim=64, d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_heads=64, ssm_conv=4,
    attn_period=6, tie_embeddings=True,
)
