"""MatQuant core: quantization math, slicing, packing, objectives."""

from repro.core.quant import (  # noqa: F401
    BF16,
    QuantConfig,
    dequantize,
    effective_bits,
    fake_quant,
    fake_quant_omni,
    minmax_scale_zero,
    quant_dequant,
    quantize,
    right_shift_stat,
    slice_bits,
    sliced_codes,
)
from repro.core.matquant import (  # noqa: F401
    cross_entropy,
    matquant_loss,
    recon_loss_multi,
    soft_ce,
)
from repro.core.packing import (  # noqa: F401
    PackedLinear,
    PackedPlane,
    pack_codes,
    packed_nbytes,
    unpack_codes,
)
from repro.core import mixnmatch, omniquant  # noqa: F401
