"""MatQuant's multi-precision joint objective (Eq. 7) + co-distillation.

The framework-level contract: a model exposes
    forward(params, batch, *, bits) -> logits
where `bits` selects the per-layer precision at which every
QuantizedLinear fake-quantizes its weights (int = uniform precision,
or a per-layer vector for Mix'n'Match). MatQuant then sums the base
algorithm's loss over R = config.bitwidths, weighted by lambda_r, and
optionally adds co-distillation edges (Section 5.2).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.quant import QuantConfig


def cross_entropy(logits: jax.Array, labels: jax.Array, mask=None) -> jax.Array:
    """Token-level CE, fp32 accumulation. labels: int ids, -1 = pad."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = logz - gold
    if mask is None:
        mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def soft_ce(student_logits: jax.Array, teacher_logits: jax.Array, mask=None):
    """Distillation loss: CE against the teacher's softmax (stop-grad)."""
    t = jax.lax.stop_gradient(
        jax.nn.log_softmax(teacher_logits.astype(jnp.float32), axis=-1)
    )
    s = jax.nn.log_softmax(student_logits.astype(jnp.float32), axis=-1)
    per_tok = -jnp.sum(jnp.exp(t) * s, axis=-1)
    if mask is None:
        mask = jnp.ones(per_tok.shape, jnp.float32)
    return jnp.sum(per_tok * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def matquant_loss(
    forward: Callable[..., jax.Array],
    params,
    batch,
    qcfg: QuantConfig,
) -> tuple[jax.Array, dict]:
    """Eq. 7: sum_r lambda_r * L(F(S(Q(theta, c), r)), y)  [+ distill].

    Returns (total_loss, metrics) where metrics carries the per-precision
    losses for logging/EXPERIMENTS tables.
    """
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)

    logits_by_bits: dict[int, jax.Array] = {}
    needed = set(qcfg.bitwidths)
    for t, s in qcfg.codistill:
        needed.add(t)
        needed.add(s)
    for r in sorted(needed, reverse=True):
        logits_by_bits[r] = forward(params, batch, bits=r)

    total = jnp.float32(0.0)
    metrics = {}
    for r, lam in zip(qcfg.bitwidths, qcfg.weights):
        l_r = cross_entropy(logits_by_bits[r], labels, mask)
        metrics[f"ce_int{r}"] = l_r
        total = total + lam * l_r
    for t, s in qcfg.codistill:
        l_d = soft_ce(logits_by_bits[s], logits_by_bits[t], mask)
        metrics[f"distill_{t}to{s}"] = l_d
        total = total + qcfg.codistill_alpha * qcfg.lambdas.get(s, 1.0) * l_d
    metrics["loss"] = total
    return total, metrics


def recon_loss_multi(
    block_fp: Callable[..., jax.Array],
    block_q: Callable[..., jax.Array],
    qparams,
    x: jax.Array,
    qcfg: QuantConfig,
) -> tuple[jax.Array, dict]:
    """OmniQuant's Eq. 5 under MatQuant: layer-wise L2 recon, summed over R.

    block_fp: x -> y with full-precision weights (the target, Eq. 7's
    y_i' = F_l(W_F, X_l)); block_q: (qparams, x, bits) -> y with
    fake-quantized weights and learnable (gamma, beta, shift, scale).
    """
    y_fp = jax.lax.stop_gradient(block_fp(x))
    total = jnp.float32(0.0)
    metrics = {}
    outs = {}
    for r in sorted(set(qcfg.bitwidths), reverse=True):
        outs[r] = block_q(qparams, x, bits=r)
    for r, lam in zip(qcfg.bitwidths, qcfg.weights):
        diff = (outs[r] - y_fp).astype(jnp.float32)
        l_r = jnp.mean(diff * diff)
        metrics[f"recon_int{r}"] = l_r
        total = total + lam * l_r
    for t, s in qcfg.codistill:
        if t not in outs:
            outs[t] = block_q(qparams, x, bits=t)
        if s not in outs:
            outs[s] = block_q(qparams, x, bits=s)
        diff = (outs[s] - jax.lax.stop_gradient(outs[t])).astype(jnp.float32)
        l_d = jnp.mean(diff * diff)
        metrics[f"distill_{t}to{s}"] = l_d
        total = total + qcfg.codistill_alpha * qcfg.lambdas.get(s, 1.0) * l_d
    metrics["loss"] = total
    return total, metrics
