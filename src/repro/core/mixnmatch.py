"""Layer-wise Mix'n'Match (Section 4.3, Appendix B).

Assign a precision from the trained set {2, 4, 8} to every layer and
serve the resulting heterogeneous model for free. The paper finds the
*Pyramid* strategy (low bits at the ends, int8 in the middle) dominates;
we implement all four strategies from Appendix B plus an exhaustive
budgeted search for small L.
"""

from __future__ import annotations

import itertools

import numpy as np

STRATEGIES = ("pyramid", "reverse_pyramid", "increasing", "decreasing")


def effective_bits(assignment) -> float:
    return float(np.mean(np.asarray(assignment, dtype=np.float64)))


def _budget_counts(num_layers: int, target_bits: float):
    """Split layers into n2/n4/n8 matching a mean-bit budget greedily."""
    best, best_err = None, float("inf")
    for n8 in range(num_layers + 1):
        for n4 in range(num_layers - n8 + 1):
            n2 = num_layers - n8 - n4
            eff = (8 * n8 + 4 * n4 + 2 * n2) / num_layers
            err = abs(eff - target_bits)
            if err < best_err:
                best, best_err = (n2, n4, n8), err
    return best


def assign(num_layers: int, target_bits: float, strategy: str = "pyramid"):
    """Per-layer bit assignment hitting `target_bits` on average.

    pyramid: int2 at both ends, int8 in the middle, int4 between --
    the paper's winning strategy (higher precision where the residual
    stream carries the most consolidated information).
    """
    n2, n4, n8 = _budget_counts(num_layers, target_bits)
    if strategy == "increasing":
        return [2] * n2 + [4] * n4 + [8] * n8
    if strategy == "decreasing":
        return [8] * n8 + [4] * n4 + [2] * n2
    if strategy == "pyramid":
        # ends get the lowest bits, middle the highest
        order = _center_out_order(num_layers)
    elif strategy == "reverse_pyramid":
        order = _center_out_order(num_layers)[::-1]
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    bits = [0] * num_layers
    ranked = [8] * n8 + [4] * n4 + [2] * n2  # center-first gets 8s
    for pos, b in zip(order, ranked):
        bits[pos] = b
    return bits


def _center_out_order(n: int):
    """Layer indices ordered center-outwards: [mid, mid±1, ...]."""
    mid = n // 2
    order = [mid]
    for d in range(1, n):
        if mid - d >= 0:
            order.append(mid - d)
        if mid + d < n:
            order.append(mid + d)
        if len(order) >= n:
            break
    return order[:n]


def sweep(num_layers: int, points: int = 13, strategy: str = "pyramid"):
    """Budget sweep 2.0 -> 8.0 bits; returns [(eff_bits, assignment)]."""
    out = []
    for t in np.linspace(2.0, 8.0, points):
        a = assign(num_layers, float(t), strategy)
        out.append((effective_bits(a), a))
    return out


def exhaustive_pareto(num_layers: int, eval_fn, bit_choices=(2, 4, 8)):
    """Exhaustive search over assignments for small L; returns the
    Pareto frontier of (effective_bits, quality). eval_fn(assignment)
    must return a scalar where LOWER is better (e.g. log pplx)."""
    results = []
    for combo in itertools.product(bit_choices, repeat=num_layers):
        results.append((effective_bits(combo), float(eval_fn(list(combo))), combo))
    results.sort()
    pareto, best = [], float("inf")
    for eff, q, combo in results:
        if q < best:
            best = q
            pareto.append((eff, q, combo))
    return pareto
