"""OmniQuant auxiliary parameters (Shao et al. 2023), Eqs. 3-4.

OmniQuant freezes the model weights W and learns, per quantized linear:
  * clipping strengths gamma, beta  (Learnable Weight Clipping)  -- Eq. 3
  * activation shift delta and scale s (Learnable Equivalent
    Transformation):  XW + b -> ((X - delta) / s) Q(W * s) + b + delta.W
                                                                 -- Eq. 4
optimized with gradient descent on the block-wise L2 reconstruction
error (Eq. 5), under MatQuant summed over R (Eq. 7).

Parameterization follows the OmniQuant reference: gamma/beta are stored
as logits and mapped through a sigmoid scaled to (0, 1+eps) so the
clipping strength stays positive and initialized at exactly 1.0.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant

_SIG_MAX = 1.5  # sigmoid ceiling; init logit chosen so sigmoid == 1/1.5


def init_aux(d_in: int, d_out: int, dtype=jnp.float32):
    """Fresh OmniQuant aux params for a (d_in, d_out) linear."""
    # sigmoid(x) * 1.5 == 1.0  =>  sigmoid(x) = 2/3  =>  x = log(2)
    logit_1 = float(jnp.log(2.0))
    return {
        "gamma_logit": jnp.full((1, d_out), logit_1, dtype),
        "beta_logit": jnp.full((1, d_out), logit_1, dtype),
        "shift": jnp.zeros((d_in,), dtype),
        "log_scale": jnp.zeros((d_in,), dtype),
    }


def clip_strengths(aux):
    gamma = jax.nn.sigmoid(aux["gamma_logit"]) * _SIG_MAX
    beta = jax.nn.sigmoid(aux["beta_logit"]) * _SIG_MAX
    return gamma, beta


def apply_linear(
    w: jax.Array,
    aux,
    x: jax.Array,
    bits: int,
    parent_bits: int = 8,
    extra_precision: bool = False,
    bias: jax.Array | None = None,
):
    """Eq. 4 forward with fake-quantized, MSB-sliced weights.

    x: (..., d_in), w: (d_in, d_out). Gradients flow to aux only
    (callers stop_gradient w, which OmniQuant freezes).
    """
    gamma, beta = clip_strengths(aux)
    s = jnp.exp(aux["log_scale"])  # positive scale, init 1
    delta = aux["shift"]
    w_scaled = w * s[:, None]
    w_q = quant.fake_quant_omni(
        w_scaled, parent_bits, bits, gamma, beta, axis=0,
        extra_precision=extra_precision,
    )
    y = ((x - delta) / s) @ w_q
    # the delta.W correction uses the *unquantized* weights (Eq. 4)
    y = y + delta @ w
    if bias is not None:
        y = y + bias
    return y.astype(x.dtype)
