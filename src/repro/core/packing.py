"""Bit-packing of quantized weight planes for the serving path.

Layout: codes are packed little-endian into int32 words along one of
the two trailing weight dims so the Pallas dequant-matmul kernel can
unpack a (block_k, block_n) tile with pure vector ops after one DMA.

Pack-axis rules
---------------
Every plane is logically (..., k, n) with quantization groups along k
(per-output-channel scales of shape (..., 1, n)). `pack_axis` selects
which trailing dim the int32 words run along:

  * ``pack_axis=-2`` (**K-packed**, the default): words are
    (..., ceil(k/cpw), n). This is the layout the Pallas kernel DMAs --
    the reduction dim is the one the kernel tiles, so up/gate/wq-type
    projections pack along it and keep their OUTPUT dim shardable
    under tensor parallelism.
  * ``pack_axis=-1`` (**N-packed**): words are (..., k, ceil(n/cpw)).
    Down/wo-type projections pack along the output dim so their
    REDUCTION dim (the residual width) stays shardable under TP; they
    are consumed by the jnp unpack twin (`kernels.ops.plane_matmul`
    routes on the axis).

Leading dims before (k, n) are batch dims: a stacked-layer plane is
(L, ...), a MoE expert stack (E, ...) or (L, E, ...).

PackedPlane static-metadata contract
------------------------------------
`PackedPlane` is the unit the serving stack passes around. It is a
registered pytree whose `bits`, `pack_axis`, and `extra_precision`
ride as STATIC metadata (aux data, not leaves). The contract:

  * the words of a plane can only be unpacked at the width they were
    packed with, so `bits` must be compile-time static -- under
    `jax.jit` it stays a Python int and the kernels never see a traced
    bitwidth;
  * two planes with different (bits, pack_axis, extra_precision) have
    different treedefs, so a jitted step closure traced for one packed
    representation cannot silently consume another -- the scheduler
    keys one compiled closure per representation
    (`core.packing.packed_rep_key`) and a tier switch retraces exactly
    once per representation, never on revisit;
  * per-layer Mix'n'Match planes each carry their own static r, which
    is what makes a heterogeneous-precision layer stack servable.

Extra precision (Errata Eq. 8)
------------------------------
For Extra-Precision MatQuant the sliced codes occupy [0, 2^r]; the
overflow bucket (code == 2^r) is exactly bit r of the (r+1)-bit code,
so it is stored out-of-band as a 1-bit bitmap plane packed along the
same axis: full code = (low r bits) + 2^r * bitmap. The kernels add
the 2^r-valued overflow term in the same pass that dequantizes the
base plane -- the TPU-friendly analogue of the paper's proposed sparse
CUDA additions. We store the bitmap densely (1 bit/weight) for
simplicity; the paper's Table 7 *effective* bits (r + overflow
fraction, bits only for weights that clip) are reported separately
(`core.quant.effective_bits`, `serve.engine.served_effective_bits`).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def codes_per_word(bits: int) -> int:
    if bits not in (1, 2, 4, 8):
        raise ValueError(f"unsupported pack width {bits}")
    return 32 // bits


def pack_codes(codes: jax.Array, bits: int, axis: int = 0) -> jax.Array:
    """Pack integer codes in [0, 2^bits) into int32 words along `axis`.

    The packed axis length becomes ceil(n / (32//bits)); codes are
    zero-padded to a whole word.
    """
    cpw = codes_per_word(bits)
    codes = jnp.moveaxis(codes, axis, 0).astype(jnp.uint32)
    n = codes.shape[0]
    pad = (-n) % cpw
    if pad:
        codes = jnp.concatenate(
            [codes, jnp.zeros((pad,) + codes.shape[1:], jnp.uint32)], axis=0
        )
    codes = codes.reshape((-1, cpw) + codes.shape[1:])
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * bits).reshape(
        (1, cpw) + (1,) * (codes.ndim - 2)
    )
    words = jnp.sum(codes << shifts, axis=1).astype(jnp.uint32)
    return jnp.moveaxis(words.view(jnp.int32), 0, axis)


def unpack_codes(words: jax.Array, bits: int, n: int, axis: int = 0) -> jax.Array:
    """Inverse of `pack_codes`; returns int32 codes, trimmed to n."""
    cpw = codes_per_word(bits)
    w = jnp.moveaxis(words, axis, 0).view(jnp.uint32)
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * bits).reshape(
        (1, cpw) + (1,) * (w.ndim - 1)
    )
    mask = jnp.uint32(2**bits - 1)
    codes = (w[:, None] >> shifts) & mask
    codes = codes.reshape((-1,) + w.shape[1:])[:n]
    return jnp.moveaxis(codes.astype(jnp.int32), 0, axis)


def packed_rep_key(bits, extra_precision: bool = False):
    """Hashable key of ONE packed serving representation.

    The single source of truth tying the router's tier ladder, the
    tier cache, and the scheduler's per-representation compiled
    closures together: an int for a uniform r-bit tier, the per-layer
    bits tuple for a packed Mix'n'Match tier, and `(key, "ep")` when
    the representation carries the extra-precision overflow bitmap
    (a different pytree structure, hence its own compile).
    """
    key = bits if isinstance(bits, int) else tuple(int(b) for b in bits)
    return (key, "ep") if extra_precision else key


@dataclasses.dataclass(eq=False)
class PackedPlane:
    """A served r-bit packed plane: what the kernels actually consume.

    Registered as a pytree with `bits`, `pack_axis`, and
    `extra_precision` as STATIC metadata (see the module docstring for
    the full contract). `overflow`, present iff `extra_precision`, is
    the 1-bit packed overflow bitmap of Extra-Precision MatQuant
    (Errata Eq. 8): full code = base + 2^bits * bitmap.

    Dequant is always `w_hat = alpha * code - beta`.
    """

    words: jax.Array        # packed r-bit codes, int32
    alpha: jax.Array        # (..., 1, n) scale (grid re-scale folded in)
    beta: jax.Array         # (..., 1, n) alpha_parent * zero_point
    overflow: jax.Array | None = None   # packed 1-bit bitmap (ep only)
    bits: int = 8           # static: the plane's bitwidth r
    pack_axis: int = -2     # static: -2 = K-packed, -1 = N-packed
    extra_precision: bool = False       # static: overflow bitmap present
    # Aliased-slice view (self-speculative decoding): `slice_bits` set
    # means the words stay packed at the PARENT width `bits` but are
    # consumed at the sliced width r = slice_bits -- the kernels apply
    # Eq. 4/6 (or Errata Eq. 8 when `slice_ep`) on the fly after the
    # unpack, so the draft plane shares the verify plane's bytes.
    slice_bits: int | None = None       # static: on-the-fly slice width
    slice_ep: bool = False              # static: slice without clamp


jax.tree_util.register_dataclass(
    PackedPlane,
    data_fields=("words", "alpha", "beta", "overflow"),
    meta_fields=("bits", "pack_axis", "extra_precision", "slice_bits",
                 "slice_ep"),
)


def slice_codes_on_grid(codes: jax.Array, c: int, r: int,
                        extra_precision: bool = False) -> jax.Array:
    """Eq. 4/6 slice of c-bit codes to r bits, vector-op form.

    `(2q + 2^(c-r)) >> (c-r+1)` is the round-half-up slice of the top r
    bits; without `extra_precision` the result clamps to [0, 2^r - 1]
    (Eq. 4/6), with it the 2^r overflow bucket survives (Errata Eq. 8).
    Bit-identical to `core.quant.sliced_codes` but built from shifts so
    the Pallas dequant tile can run it on the VPU.
    """
    if r == c:
        return codes
    sliced = (2 * codes + (1 << (c - r))) >> (c - r + 1)
    if extra_precision:
        return sliced
    return jnp.minimum(sliced, (1 << r) - 1)


def sliced_view(plane: PackedPlane, bits: int,
                extra_precision: bool = False) -> PackedPlane:
    """Zero-copy r-bit draft view of a resident parent plane.

    The returned plane ALIASES `plane.words` (and `beta` -- the paper's
    `beta_r = alpha_parent * zero` is r-independent); only `alpha` is a
    new (..., 1, n) array, rescaled by the exact power of two
    `2^(c - r)` so float dequant stays bit-identical to a materialized
    r-bit plane. The kernels see `slice_bits`/`slice_ep` as static
    metadata and apply the MSB slice after the unpack: this is how the
    int2 draft model of self-speculative decoding costs zero extra
    plane bytes on top of the resident int8 tier.
    """
    c = plane.bits
    if plane.slice_bits is not None:
        raise ValueError("cannot re-slice an already-sliced view")
    if plane.extra_precision:
        raise ValueError("sliced_view needs a base (non-ep) parent plane")
    if not 1 <= bits <= c:
        raise ValueError(f"slice width {bits} not in [1, {c}]")
    if bits == c and not extra_precision:
        return plane
    scale = jnp.asarray(2 ** (c - bits), plane.alpha.dtype)
    return PackedPlane(words=plane.words, alpha=plane.alpha * scale,
                       beta=plane.beta, overflow=None, bits=c,
                       pack_axis=plane.pack_axis, extra_precision=False,
                       slice_bits=bits, slice_ep=extra_precision)


@dataclasses.dataclass
class PackedLinear:
    """A packed c-bit parent from which any r <= c model can be served.

    Stores the *parent* (int8 by default) codes packed, plus the shared
    (alpha, z). Slicing to a lower precision happens at load time
    (`materialize`) producing the r-bit packed plane the kernel consumes;
    this is exactly the deployment flow of Section 5.4.

    Weights may carry leading (e.g. stacked-layer) dims; the trailing two
    are always (k, n) and quantization groups run along k. `pack_axis`
    selects which of the two the codes pack along: -2 (the default, the
    reduction dim the Pallas kernel DMAs) or -1 (down/wo-type projections
    whose packed plane must keep its reduction dim shardable under TP).
    """

    words: jax.Array        # packed parent codes, int32
    alpha: jax.Array        # (..., 1, n) scale
    zero: jax.Array         # (..., 1, n) zero point
    k: int                  # logical reduction dim
    n: int                  # output dim
    parent_bits: int = 8
    pack_axis: int = -2     # axis the codes are packed along

    @classmethod
    def from_weights(cls, w: jax.Array, parent_bits: int = 8,
                     pack_axis: int = -2):
        from repro.core import quant

        q, alpha, z = quant.quantize(w, parent_bits, axis=-2)
        words = pack_codes(q, parent_bits, axis=pack_axis)
        return cls(words=words, alpha=alpha, zero=z,
                   k=w.shape[-2], n=w.shape[-1], parent_bits=parent_bits,
                   pack_axis=pack_axis)

    @property
    def _packed_len(self) -> int:
        """Logical (unpacked) length of the packed axis."""
        return self.k if self.pack_axis in (-2, self.words.ndim - 2) else self.n

    def materialize(self, bits: int, extra_precision: bool = False):
        """Slice the parent to `bits` and re-pack for serving.

        Returns (packed_words, alpha_r, zero_r[, overflow_bitmap]) where
        dequant is w_hat = alpha_r * (codes * 2^(c-r) - z)  -- we fold
        the 2^(c-r) grid re-scale into alpha_r so the kernel's dequant
        is always `alpha * code - beta` regardless of r.

        With `extra_precision` (Errata Eq. 8) the sliced codes occupy
        [0, 2^r] and are split bit-exactly: the base plane keeps the
        low r bits, the 1-bit bitmap plane is bit r (the overflow
        bucket), so full code = base + 2^r * bitmap and the kernels
        add the 2^r-valued overflow term in the same dequant pass.
        """
        from repro.core import quant

        c = self.parent_bits
        parent = unpack_codes(self.words, c, self._packed_len,
                              axis=self.pack_axis)
        codes = quant.sliced_codes(parent, c, bits, extra_precision=extra_precision)
        scale = jnp.asarray(2 ** (c - bits), self.alpha.dtype)
        alpha_r = self.alpha * scale
        beta_r = self.alpha * self.zero
        if extra_precision:
            overflow = codes >> bits          # bit r: the overflow bucket
            base = codes & (2**bits - 1)      # low r bits
            return (
                pack_codes(base, bits, axis=self.pack_axis),
                alpha_r,
                beta_r,
                pack_codes(overflow, 1, axis=self.pack_axis),
            )
        return pack_codes(codes, bits, axis=self.pack_axis), alpha_r, beta_r

    def materialize_plane(self, bits: int,
                          extra_precision: bool = False) -> PackedPlane:
        """`materialize` packaged as the PackedPlane the kernels consume."""
        mat = self.materialize(bits, extra_precision=extra_precision)
        words, alpha_r, beta_r = mat[:3]
        return PackedPlane(words=words, alpha=alpha_r, beta=beta_r,
                           overflow=mat[3] if extra_precision else None,
                           bits=bits, pack_axis=self.pack_axis,
                           extra_precision=extra_precision)

    def layer(self, idx: int) -> "PackedLinear":
        """The parent of ONE stacked layer: index the leading dim.

        A (L, ..., k, n) parent becomes the (..., k, n) parent of layer
        `idx`; (k, n) and pack_axis are unchanged (both are trailing-dim
        properties). This is the per-layer slicing step of a packed
        Mix'n'Match tier: layer l is materialized at its own r."""
        if self.words.ndim < 3:
            raise ValueError("layer() needs a stacked (leading-dim) parent")
        return PackedLinear(words=self.words[idx], alpha=self.alpha[idx],
                            zero=self.zero[idx], k=self.k, n=self.n,
                            parent_bits=self.parent_bits,
                            pack_axis=self.pack_axis)


def packed_nbytes(k: int, n: int, bits: int, pack_axis: int = -2,
                  extra_precision: bool = False,
                  model_parallel: int = 1) -> int:
    """HBM bytes of one packed (k, n) plane -- roofline accounting.

    pack_axis selects which dim the int32 words run along: -2 packs the
    reduction dim k (ceil(k/cpw) x n words), -1 packs the output dim n
    (k x ceil(n/cpw) words -- down/wo-type planes). The two differ
    whenever the packed dim is not a multiple of codes-per-word.
    `extra_precision` adds the densely stored 1-bit overflow bitmap
    (cpw = 32) packed along the same axis.

    `model_parallel` > 1 returns the PER-DEVICE bytes of the plane on a
    TP mesh: the UNPACKED trailing dim is the sharded one (the output
    dim n for K-packed planes, the reduction dim k for N-packed
    down/wo-type planes -- exactly the placement
    `serve.engine.packed_axes` resolves). When the sharded dim divides
    evenly, per-device bytes are total / model_parallel; when it does
    not, the sharding resolver leaves that plane REPLICATED, so this
    returns the full plane size to match (per-device == total).
    """
    mp = model_parallel
    if mp < 1:
        raise ValueError(f"model_parallel must be >= 1, got {mp}")
    cpw = codes_per_word(bits)
    if pack_axis in (-1, 1):
        k = k // mp if k % mp == 0 else k      # ragged -> replicated
        nbytes = k * int(np.ceil(n / cpw)) * 4
        if extra_precision:
            nbytes += k * int(np.ceil(n / 32)) * 4
        return nbytes
    n = n // mp if n % mp == 0 else n          # ragged -> replicated
    nbytes = int(np.ceil(k / cpw)) * n * 4
    if extra_precision:
        nbytes += int(np.ceil(k / 32)) * n * 4
    return nbytes
