"""Core quantization math for Matryoshka Quantization (MatQuant).

Implements, exactly per the paper:

  * MinMax quantization  Q_MM(w, c)            (Eq. 1)
  * OmniQuant's learnable-clip variant         (Eq. 3)
  * The MSB slicing operator  S(q^c, r)        (Eq. 6, Appendix A)
  * The Errata "extra precision" slice          (Eq. 8)  -- no clamp,
    2^r + 1 buckets, the overflow bucket capturing outliers.
  * Straight-through-estimator (STE) fake quantization used by both QAT
    and OmniQuant training paths.

All functions are pure jnp and shard-transparent: they operate on the
trailing `group` axis (per-output-channel groups by default) so GSPMD
can propagate shardings through them unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

# Numerical guard for degenerate (constant) weight groups.
_EPS = 1e-8


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Configuration of the MatQuant scheme threaded through the model.

    Attributes:
      bitwidths: precisions jointly optimized (paper default (8, 4, 2)).
      parent_bits: the container precision c; slices are taken from it.
      mode: 'bf16' | 'qat' | 'omniquant' | 'serve_packed'.
      scope: 'ffn' (paper default) or 'ffn+attn' (Section 5.3).
      extra_precision: Errata Eq. 8 -- keep the overflow bucket.
      weights: loss re-weighting lambda_r per bitwidth (Table 3).
      codistill: tuple of (teacher_bits, student_bits) distillation
        edges, e.g. ((8, 2),) for the paper's [8, 4, 2, 8->2] config.
      codistill_alpha: weight of distillation term (paper: equal weight
        with the ground-truth term).
      group_axis: axis treated as the quantization group (per output
        channel = -1 for a (d_in, d_out) kernel quantized column-wise).
      packed_bits: serve path -- weights stored as packed r-bit codes.
      packed_kernel: route packed planes through the Pallas dequant
        matmul (kernels.ops.plane_matmul) instead of the jnp unpack
        twin; set on TPU (or with interpret mode for kernel tests).
    """

    bitwidths: tuple[int, ...] = (8, 4, 2)
    parent_bits: int = 8
    mode: str = "qat"
    scope: str = "ffn"
    extra_precision: bool = False
    weights: tuple[float, ...] = (0.1, 0.1, 1.0)
    codistill: tuple[tuple[int, int], ...] = ()
    codistill_alpha: float = 1.0
    group_axis: int = 0
    packed_bits: int = 0     # serve path: weights stored as packed codes
    packed_kernel: bool = False   # consume packed planes via the Pallas kernel

    def __post_init__(self):
        if len(self.weights) != len(self.bitwidths):
            raise ValueError(
                f"weights {self.weights} must match bitwidths {self.bitwidths}"
            )
        if max(self.bitwidths) > self.parent_bits:
            raise ValueError("bitwidths cannot exceed parent_bits")

    @property
    def lambdas(self) -> dict[int, float]:
        return dict(zip(self.bitwidths, self.weights))


BF16 = QuantConfig(mode="bf16")


# ---------------------------------------------------------------------------
# MinMax quantization (Eq. 1) and the OmniQuant variant (Eq. 3)
# ---------------------------------------------------------------------------


def minmax_scale_zero(
    w: jax.Array,
    c: int,
    axis: int | Sequence[int] = 0,
    gamma: jax.Array | None = None,
    beta: jax.Array | None = None,
):
    """Scale alpha and zero-point z of c-bit asymmetric MinMax quant.

    With OmniQuant's learnable clipping strengths gamma/beta (Eq. 3):
      alpha = (gamma*max - beta*min) / (2^c - 1),  z = -beta*min/alpha.
    gamma = beta = 1 recovers plain MinMax (Eq. 1).
    """
    w_max = jnp.max(w, axis=axis, keepdims=True)
    w_min = jnp.min(w, axis=axis, keepdims=True)
    if gamma is not None:
        w_max = gamma * w_max
    if beta is not None:
        w_min = beta * w_min
    levels = jnp.asarray(2**c - 1, w.dtype)
    alpha = (w_max - w_min) / levels
    # Guard: constant group -> alpha == 0; quantize everything to z.
    alpha = jnp.where(jnp.abs(alpha) < _EPS, _EPS, alpha)
    z = -w_min / alpha
    return alpha, z


def quantize(
    w: jax.Array,
    c: int,
    axis: int | Sequence[int] = 0,
    gamma: jax.Array | None = None,
    beta: jax.Array | None = None,
):
    """Q_MM(w, c): c-bit integer codes plus (alpha, z) for dequant.

    Returns codes as int32 in [0, 2^c - 1].
    """
    alpha, z = minmax_scale_zero(w, c, axis=axis, gamma=gamma, beta=beta)
    q = jnp.clip(jnp.round(w / alpha + z), 0, 2**c - 1)
    return q.astype(jnp.int32), alpha, z


def dequantize(q: jax.Array, alpha: jax.Array, z: jax.Array, dtype=jnp.float32):
    """Inverse of `quantize`: w_hat = alpha * (q - z)."""
    return (alpha * (q.astype(alpha.dtype) - z)).astype(dtype)


# ---------------------------------------------------------------------------
# The Matryoshka slicing operator (Eq. 6 / Eq. 8) -- the paper's core op.
# ---------------------------------------------------------------------------


def slice_bits(q_c: jax.Array, c: int, r, extra_precision: bool = False):
    """S(q^c, r): slice the r most significant bits of c-bit codes.

    Per Appendix A the (r+1)-th MSB decides rounding: fractional part of
    q / 2^(c-r) >= 0.5 iff that bit is set, so floor(q/2^(c-r) + 0.5)
    (in exact integer arithmetic: (2q + 2^(c-r)) // 2^(c-r+1)) matches
    the paper's "round up when the next bit is set" semantics, including
    the worked examples S(234,2)=192, S(53,2)=64, S(240,2)=192.

    `r` may be a Python int or a traced int array (dynamic per-layer
    precision inside lax.scan -- Mix'n'Match). When r == c the formula
    reduces to the identity ((2q+1)//2 == q).

    Returns codes *re-scaled to the parent grid*, i.e. values in
    {0, 2^(c-r), ..., (2^r - 1) * 2^(c-r)}  (plus 2^c when
    extra_precision=True, the Errata Eq. 8 overflow bucket).
    """
    if isinstance(r, int):
        if r > c:
            raise ValueError(f"cannot slice {r} bits from {c}")
        if r == c:
            return q_c
    shift = _pow2(c - r, q_c.dtype)
    rounded = jnp.floor_divide(2 * q_c + shift, 2 * shift)
    if not extra_precision:
        rounded = jnp.clip(rounded, 0, _pow2(r, q_c.dtype) - 1)
    return (rounded * shift).astype(q_c.dtype)


def sliced_codes(q_c: jax.Array, c: int, r, extra_precision: bool = False):
    """Like `slice_bits` but returns raw r-bit codes in [0, 2^r (-1)]."""
    if isinstance(r, int) and r == c:
        return q_c
    shift = _pow2(c - r, q_c.dtype)
    rounded = jnp.floor_divide(2 * q_c + shift, 2 * shift)
    if not extra_precision:
        rounded = jnp.clip(rounded, 0, _pow2(r, q_c.dtype) - 1)
    return rounded.astype(q_c.dtype)


def _pow2(e, dtype=jnp.int32):
    """2**e for python-int or traced-int e (left shift keeps it exact)."""
    if isinstance(e, int):
        return jnp.asarray(2**e, dtype)
    return jnp.left_shift(jnp.asarray(1, dtype), e.astype(dtype))


def effective_bits(q_c: jax.Array, c: int, r: int) -> jax.Array:
    """Average bits/param of the extra-precision representation (Table 7).

    Base cost r bits; weights that land in the overflow bucket (code ==
    2^r after rounding without clamp) cost one extra bit each.
    """
    shift = 2 ** (c - r)
    rounded = jnp.floor_divide(2 * q_c + shift, 2 * shift)
    frac_overflow = jnp.mean((rounded >= 2**r).astype(jnp.float32))
    return r + frac_overflow


# ---------------------------------------------------------------------------
# STE fake quantization -- the differentiable path used in training.
# ---------------------------------------------------------------------------


def quant_dequant(
    w: jax.Array,
    c: int,
    r,
    axis: int | Sequence[int] = 0,
    extra_precision: bool = False,
):
    """Quantize to c bits, slice to r MSBs, dequantize (no gradient path)."""
    q, alpha, z = quantize(w, c, axis=axis)
    q_r = slice_bits(q, c, r, extra_precision=extra_precision)
    return dequantize(q_r, alpha, z, dtype=w.dtype)


def fake_quant(
    w: jax.Array,
    c: int,
    r,
    axis: int | Sequence[int] = 0,
    extra_precision: bool = False,
):
    """STE fake quantization: forward = S(Q(w, c), r) dequantized,
    backward = identity (Bengio et al. 2013).

    Implemented as w + sg(qdq(w) - w) so it composes with traced `r`
    (dynamic per-layer precision) without a custom_vjp.
    """
    w_hat = quant_dequant(w, c, r, axis=axis, extra_precision=extra_precision)
    return w + jax.lax.stop_gradient(w_hat - w)


def fake_quant_omni(
    w: jax.Array,
    c: int,
    r,
    gamma: jax.Array,
    beta: jax.Array,
    axis: int = 0,
    extra_precision: bool = False,
):
    """OmniQuant fake quant: STE w.r.t. w, *differentiable* in gamma/beta.

    OmniQuant freezes w and trains (gamma, beta); round/floor are the
    only non-differentiable ops, handled by inline STEs. `r` may be a
    traced int (per-layer Mix'n'Match); the slice formula reduces to the
    identity when r == c, so no Python branching on r is needed.
    """
    alpha, z = minmax_scale_zero(w, c, axis=axis, gamma=gamma, beta=beta)
    x = w / alpha + z
    x_rounded = x + jax.lax.stop_gradient(jnp.round(x) - x)  # STE round
    q = jnp.clip(x_rounded, 0, 2**c - 1)
    if isinstance(r, int):
        shift = float(2 ** (c - r))
        rmax = float(2**r - 1)
    else:
        shift = jnp.exp2((c - r).astype(jnp.float32))
        rmax = jnp.exp2(r.astype(jnp.float32)) - 1.0
    y = (2.0 * q + shift) / (2.0 * shift)
    y_fl = y + jax.lax.stop_gradient(jnp.floor(y) - y)       # STE floor
    if not extra_precision:
        y_fl = jnp.clip(y_fl, 0, rmax)
    q = y_fl * shift
    return (alpha * (q - z)).astype(w.dtype)


def right_shift_stat(w: jax.Array, c: int = 8, axis: int = 0) -> jax.Array:
    """Mean quantized code -- Fig. 1c's 'right shifted distribution' stat.

    MatQuant-trained weights use more high-valued buckets; comparing this
    statistic against a baseline-quantized model reproduces Fig. 1c
    quantitatively.
    """
    q, _, _ = quantize(w, c, axis=axis)
    return jnp.mean(q.astype(jnp.float32))
