from repro.data.pipeline import (  # noqa: F401
    DataConfig,
    SyntheticCorpus,
    host_sharded_batches,
)
