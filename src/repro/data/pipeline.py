"""Data pipeline: synthetic Zipf-Markov corpus + host-sharded loader.

C4 is unavailable offline, so training/calibration data comes from a
*learnable* synthetic language: a first-order Markov chain whose
transition rows are Zipf-distributed over a sparse support, with a
small periodic "grammar" component. Models trained on it exhibit real
loss curves and real quantization-sensitivity, which is what the
paper's qualitative claims need (DESIGN.md §5).

The loader is multi-host aware: every host draws only its own batch
shard, deterministically from (seed, step, host_id) -- restart-safe and
elastic (a host count change just re-partitions the global batch).
Double-buffered prefetch overlaps host-side generation with device
compute.
"""

from __future__ import annotations

import dataclasses
import threading
import queue as queue_mod

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 512
    seq_len: int = 128
    global_batch: int = 32
    seed: int = 0
    branching: int = 24        # out-degree of each Markov state
    zipf_a: float = 1.3        # Zipf exponent over successors


class SyntheticCorpus:
    """Deterministic Zipf-Markov token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V, B = cfg.vocab_size, cfg.branching
        # per-state successor sets + Zipf weights
        self.successors = rng.integers(0, V, size=(V, B), dtype=np.int32)
        ranks = np.arange(1, B + 1, dtype=np.float64)
        w = ranks ** (-cfg.zipf_a)
        self.weights = (w / w.sum()).astype(np.float64)

    def sample(self, rng: np.random.Generator, batch: int, seq_len: int) -> np.ndarray:
        V = self.cfg.vocab_size
        out = np.empty((batch, seq_len + 1), dtype=np.int32)
        state = rng.integers(0, V, size=batch).astype(np.int32)
        choices = rng.choice(self.cfg.branching, size=(batch, seq_len + 1),
                             p=self.weights)
        for t in range(seq_len + 1):
            out[:, t] = state
            state = self.successors[state, choices[:, t]]
        return out

    def batch(self, step: int, batch: int, seq_len: int, host_id: int = 0):
        """Deterministic (step, host) batch -> {'tokens', 'labels'}."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, host_id])
        )
        toks = self.sample(rng, batch, seq_len)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def host_sharded_batches(
    corpus: SyntheticCorpus,
    *,
    start_step: int = 0,
    num_steps: int,
    global_batch: int,
    seq_len: int,
    host_id: int = 0,
    num_hosts: int = 1,
    prefetch: int = 2,
):
    """Generator of per-host batches with background prefetch."""
    per_host = global_batch // num_hosts
    assert per_host * num_hosts == global_batch, (global_batch, num_hosts)
    q: queue_mod.Queue = queue_mod.Queue(maxsize=prefetch)
    stop = threading.Event()

    def producer():
        for step in range(start_step, start_step + num_steps):
            if stop.is_set():
                return
            q.put(corpus.batch(step, per_host, seq_len, host_id))
        q.put(None)

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    try:
        while True:
            item = q.get()
            if item is None:
                return
            yield item
    finally:
        stop.set()
