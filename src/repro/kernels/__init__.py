"""Pallas TPU kernels for MatQuant's compute hot-spots.

quant_matmul  -- packed r-bit dequant matmul (serving/decode path)
fused_quantize -- one-pass minmax + multi-precision slice (QAT path)
paged_attend  -- fused paged decode attention: in-tile Matryoshka KV
                 unpack/slice/FMA + online softmax off the page store
Each kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper + dispatch), ref.py (pure-jnp oracle).
"""
from repro.kernels import ops, ref  # noqa: F401
