"""Pallas TPU kernel: fused MinMax-quantize + multi-precision slice.

QAT's forward fake-quantizes every weight tensor once per target
precision: naively that is |R| reads of W from HBM plus |R| minmax
reductions. This kernel performs ONE HBM read of a (K, block_n) stripe
into VMEM, ONE minmax reduction, and emits all |R| sliced-dequantized
planes -- exactly the fused op MatQuant training wants. (XLA often
cannot fuse across the three forward passes because each consumer sits
in a different layer invocation.)

Grid: 1-D over N stripes; the full K column must fit VMEM, which holds
for every assigned arch (K <= 29568 at fp32 * 128 cols = 15.1 MB; the
ops.py wrapper drops block_n to keep stripe bytes under the cap).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, *o_refs, bitwidths, parent_bits, extra_precision):
    w = w_ref[...].astype(jnp.float32)               # (K, bn)
    c = parent_bits
    levels = (1 << c) - 1
    w_max = jnp.max(w, axis=0, keepdims=True)
    w_min = jnp.min(w, axis=0, keepdims=True)
    alpha = (w_max - w_min) / levels
    alpha = jnp.where(jnp.abs(alpha) < 1e-8, 1e-8, alpha)
    z = -w_min / alpha
    q = jnp.clip(jnp.round(w / alpha + z), 0, levels).astype(jnp.int32)
    for o_ref, r in zip(o_refs, bitwidths):
        if r == c:
            q_r = q
        else:
            shift = 1 << (c - r)
            q_r = (2 * q + shift) // (2 * shift)
            if not extra_precision:
                q_r = jnp.clip(q_r, 0, (1 << r) - 1)
            q_r = q_r * shift
        o_ref[...] = (alpha * (q_r.astype(jnp.float32) - z)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bitwidths", "parent_bits", "extra_precision",
                     "block_n", "interpret"),
)
def fused_quantize_pallas(
    w: jax.Array,                 # (K, N)
    *,
    bitwidths: tuple[int, ...],
    parent_bits: int = 8,
    extra_precision: bool = False,
    block_n: int = 128,
    interpret: bool = False,
):
    K, N = w.shape
    assert N % block_n == 0, (N, block_n)
    outs = pl.pallas_call(
        functools.partial(_kernel, bitwidths=bitwidths,
                          parent_bits=parent_bits,
                          extra_precision=extra_precision),
        grid=(N // block_n,),
        in_specs=[pl.BlockSpec((K, block_n), lambda j: (0, j))],
        out_specs=[pl.BlockSpec((K, block_n), lambda j: (0, j))
                   for _ in bitwidths],
        out_shape=[jax.ShapeDtypeStruct((K, N), w.dtype) for _ in bitwidths],
        interpret=interpret,
    )(w)
    return tuple(outs)
