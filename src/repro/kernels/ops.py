"""Jit'd public wrappers around the Pallas kernels with backend dispatch.

* On TPU: compiled pallas_call.
* On CPU (this container): interpret=True executes the kernel body in
  Python for correctness tests; the serving engine's jnp path (identical
  math) is what the dry-run lowers, keeping XLA cost analysis honest.

Wrappers also handle padding to block multiples and the Extra-Precision
composition: the 1-bit overflow bitmap rides into the SAME kernel call
as the base plane and contributes its 2^bits-valued term inside the
dequant step (full code = base + 2^bits * bitmap).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.kernels import ref
from repro.kernels.fused_quantize import fused_quantize_pallas
from repro.kernels.paged_attention import paged_attend_pallas
from repro.kernels.quant_matmul import (quant_matmul_experts_pallas,
                                        quant_matmul_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def _fit_blocks(M, K, N, cpw, block_m, block_n, block_k):
    """Shrink requested block sizes to ones the kernel accepts: block_m
    covers ragged M (padded inside the kernel), block_k must divide K
    and be a multiple of codes-per-word, block_n must divide N."""
    bm = min(block_m, max(8, M))
    bk = min(block_k, K)
    while K % bk or bk % cpw:
        bk -= 1
    bn = min(block_n, N)
    while N % bn:
        bn -= 1
    return bm, bk, bn


def quant_matmul(x, words, alpha, beta, *, bits, overflow_words=None,
                 slice_bits=None, slice_ep=False,
                 interpret: bool | None = None,
                 block_m=128, block_n=128, block_k=512):
    """y = x @ dequant(words). Extra precision composes the 1-bit
    overflow bitmap in the SAME kernel call: full code = base +
    2^bits * bitmap, so an ep plane costs one extra word DMA per tile
    instead of a second kernel launch.

    x: (..., K); words: (ceil(K/cpw), N). Returns (..., N).
    """
    if interpret is None:
        interpret = not _on_tpu()
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = words.shape[1]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]

    # with an overflow bitmap the K tile must also cover whole 1-bit
    # words: cpw(bits) always divides 32, so fit against cpw = 32
    cpw = 32 if overflow_words is not None else packing.codes_per_word(bits)
    bm, bk, bn = _fit_blocks(M, K, N, cpw, block_m, block_n, block_k)
    y = quant_matmul_pallas(
        x2, words, alpha.astype(jnp.float32), beta.astype(jnp.float32),
        overflow_words,
        bits=bits, block_m=bm, block_n=bn, block_k=bk, interpret=interpret,
        slice_bits=slice_bits, slice_ep=slice_ep)
    return y.reshape(lead + (N,)).astype(x.dtype)


def fused_quantize(w, *, bitwidths, parent_bits=8, extra_precision=False,
                   interpret: bool | None = None, vmem_budget=12 * 2**20):
    """All-precision fake-quantized planes of w: tuple, one per r."""
    if interpret is None:
        interpret = not _on_tpu()
    K, N = w.shape
    # choose block_n so the fp32 stripe fits the VMEM budget
    bn = 128
    while K * bn * 4 * (1 + len(bitwidths)) > vmem_budget and bn > 8:
        bn //= 2
    w_p, pad_n = _pad_to(w, bn, 1)
    outs = fused_quantize_pallas(
        w_p, bitwidths=tuple(bitwidths), parent_bits=parent_bits,
        extra_precision=extra_precision, block_n=bn, interpret=interpret)
    if pad_n:
        outs = tuple(o[:, :N] for o in outs)
    return outs


def quant_matmul_experts(x, words, alpha, beta, *, bits, overflow_words=None,
                         slice_bits=None, slice_ep=False,
                         interpret: bool | None = None,
                         block_m=128, block_n=128, block_k=512):
    """Batched-over-experts `quant_matmul`: x (E, M, K) against one
    packed K-packed plane per expert, words (E, ceil(K/cpw), N). The
    Pallas kernel runs with its grid extended over E; an extra-precision
    expert stack passes its (E, K/32, N) bitmap into the same call.
    Returns (E, M, N).
    """
    if interpret is None:
        interpret = not _on_tpu()
    E, M, K = x.shape
    N = words.shape[-1]
    cpw = 32 if overflow_words is not None else packing.codes_per_word(bits)
    bm, bk, bn = _fit_blocks(M, K, N, cpw, block_m, block_n, block_k)
    return quant_matmul_experts_pallas(
        x, words, alpha.astype(jnp.float32), beta.astype(jnp.float32),
        overflow_words,
        bits=bits, block_m=bm, block_n=bn, block_k=bk, interpret=interpret,
        slice_bits=slice_bits, slice_ep=slice_ep)


def paged_attend(q, cache_l, ptab, pos, *, kv_bits=None,
                 interpret: bool | None = None):
    """Fused paged decode attention off one layer's page store.

    The hot-path twin of `attention.gather_slot_view` +
    `attention._grouped_attend`: instead of materializing the slot's
    dequantized (B, cache_len, kh, hd) view in HBM, the Pallas kernel
    unpacks, MSB-slices (static `kv_bits`), dequantizes, and folds each
    page into an online softmax in-tile. q: (B, kh, G, hd) kv-head-major
    query groups; cache_l one layer's page-store leaves (kp/vp [+
    ks/kb/vs/vb]); ptab the sentinel-padded page table; pos (B,) slot
    positions. Returns fp32 (B, kh, G, hd).
    """
    if interpret is None:
        interpret = not _on_tpu()
    if "ks" in cache_l:
        return paged_attend_pallas(
            q, ptab, pos, cache_l["kp"], cache_l["vp"], cache_l["ks"],
            cache_l["kb"], cache_l["vs"], cache_l["vb"],
            kv_bits=kv_bits, interpret=interpret)
    return paged_attend_pallas(q, ptab, pos, cache_l["kp"], cache_l["vp"],
                               kv_bits=None, interpret=interpret)


def _plane_fields(plane, bits):
    """(words, alpha, beta, overflow, bits, pack_axis, slice_bits,
    slice_ep) of a packed plane.

    `plane` must be a `core.packing.PackedPlane`: bits, pack_axis, and
    extra_precision come from its static metadata -- the authoritative
    source (a conflicting `bits=` is an error: unpacking at any other
    width misreads the words). A plane with `slice_bits` set is an
    aliased draft view (`core.packing.sliced_view`): words packed at
    the parent width `bits`, MSB-sliced to `slice_bits` on the fly
    after the unpack. (matlint R2 retired the legacy
    `{'words','alpha','beta'}` dict planes: no in-tree producer builds
    them, and their bits/pack-axis inference violated the
    static-metadata contract -- see docs/contracts.md.)"""
    if not isinstance(plane, packing.PackedPlane):
        raise TypeError(
            f"plane must be a core.packing.PackedPlane, got "
            f"{type(plane).__name__}; legacy dict planes are no longer "
            f"served (static-metadata contract, docs/contracts.md R2)")
    if bits is not None and bits != plane.bits:
        raise ValueError(
            f"bits={bits} conflicts with the plane's static bitwidth "
            f"{plane.bits}; the words can only be unpacked at the "
            f"width they were packed with")
    return (plane.words, plane.alpha, plane.beta, plane.overflow,
            plane.bits, plane.pack_axis, plane.slice_bits,
            plane.slice_ep)


def plane_matmul(x, plane, *, bits: int | None = None,
                 use_kernel: bool = False, interpret: bool | None = None):
    """Bits-static entry point for one packed weight plane.

    The serving integration point: `models.common.qlinear` (and
    `models.ffn.apply_moe` for expert stacks) hands every packed weight
    plane here. `plane` is a `core.packing.PackedPlane`: bits,
    pack_axis, and extra_precision come from its static metadata
    (passing a different `bits=` raises).

    Dispatch table (rows checked in order; `use_kernel` means TPU, or
    interpret mode in kernel tests):

      plane layout            x shape     use_kernel  executes
      ----------------------  ----------  ----------  ----------------------
      K-packed 2-D,           (..., K)    yes         Pallas dequant-matmul
      K % block constraints                           (`quant_matmul`)
      hold (incl. K % 32
      for the ep bitmap)
      K-packed expert stack   (E, M, K)   yes         expert-batched Pallas
      words (E, ceil(K/cpw),                          kernel, grid over E
      N), same constraints                            (`quant_matmul_experts`)
      N-packed (down/wo),     any         --          jnp unpack twin
      non-tiling shapes, or                           (vmapped over E for
      use_kernel=False                                stacks)

    The jnp twin is identical math, so the paths are interchangeable
    per-plane. Extra-precision planes compose their overflow bitmap on
    EVERY path: the kernels add the 2^bits-valued term in the dequant
    tile, the twin adds it to the unpacked codes.

    x: (..., K), or (E, M, K) against an expert stack; returns (..., N)
    in x.dtype (no bias).
    """
    (words, alpha, beta, overflow, bits, pack_axis, slice_bits,
     slice_ep) = _plane_fields(plane, bits)
    K, N = x.shape[-1], alpha.shape[-1]
    cpw = packing.codes_per_word(bits)
    packed_k = pack_axis in (-2, words.ndim - 2)
    # the ep bitmap packs 32 codes/word, so the kernel additionally
    # needs K to tile in whole bitmap words
    ep_ok = overflow is None or K % 32 == 0
    if use_kernel and packed_k and words.shape[-2] * cpw == K and ep_ok:
        if words.ndim == 2:
            return quant_matmul(x, words, alpha, beta, bits=bits,
                                overflow_words=overflow,
                                slice_bits=slice_bits, slice_ep=slice_ep,
                                interpret=interpret)
        if words.ndim == 3 and x.ndim == 3 and x.shape[0] == words.shape[0]:
            return quant_matmul_experts(x, words, alpha, beta, bits=bits,
                                        overflow_words=overflow,
                                        slice_bits=slice_bits,
                                        slice_ep=slice_ep,
                                        interpret=interpret)
    if packed_k:
        codes = packing.unpack_codes(words, bits, K, axis=-2)
        if overflow is not None:
            codes = codes + (packing.unpack_codes(overflow, 1, K, axis=-2)
                             << bits)
    else:
        codes = packing.unpack_codes(words, bits, N, axis=-1)
        if overflow is not None:
            codes = codes + (packing.unpack_codes(overflow, 1, N, axis=-1)
                             << bits)
    if slice_bits is not None:
        codes = packing.slice_codes_on_grid(codes, bits, slice_bits,
                                            extra_precision=slice_ep)
    w_hat = (alpha * codes.astype(jnp.float32) - beta).astype(x.dtype)
    if words.ndim == 2:
        return x @ w_hat
    # expert stack on the jnp twin: vmap the 2-D twin over E
    return jax.vmap(jnp.matmul)(x, w_hat)


def serve_linear(x, packed: packing.PackedLinear, bits: int,
                 extra_precision: bool = False, interpret: bool | None = None):
    """End-to-end packed serving linear: slice parent -> plane matmul.

    Routes through `plane_matmul`, which honors the parent's pack_axis:
    K-packed planes hit the Pallas kernel, N-packed (down/wo-type)
    planes take the jnp unpack twin -- `quant_matmul` alone would read
    an N-packed (k, ceil(n/cpw)) word array as if it were K-packed.
    Extra precision rides the 1-bit overflow bitmap on the plane itself
    (PackedPlane.overflow), composed in the same dispatch.
    """
    plane = packed.materialize_plane(bits, extra_precision=extra_precision)
    return plane_matmul(x, plane, use_kernel=True, interpret=interpret)


__all__ = ["quant_matmul", "quant_matmul_experts", "plane_matmul",
           "fused_quantize", "serve_linear", "paged_attend", "ref"]
