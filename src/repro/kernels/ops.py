"""Jit'd public wrappers around the Pallas kernels with backend dispatch.

* On TPU: compiled pallas_call.
* On CPU (this container): interpret=True executes the kernel body in
  Python for correctness tests; the serving engine's jnp path (identical
  math) is what the dry-run lowers, keeping XLA cost analysis honest.

Wrappers also handle padding to block multiples and the Extra-Precision
composition (base plane + 1-bit overflow plane through the same kernel).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.kernels import ref
from repro.kernels.fused_quantize import fused_quantize_pallas
from repro.kernels.quant_matmul import quant_matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def quant_matmul(x, words, alpha, beta, *, bits, overflow_words=None,
                 interpret: bool | None = None,
                 block_m=128, block_n=128, block_k=512):
    """y = x @ dequant(words). Extra precision adds the overflow plane.

    x: (..., K); words: (ceil(K/cpw), N). Returns (..., N).
    """
    if interpret is None:
        interpret = not _on_tpu()
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = words.shape[1]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]

    cpw = packing.codes_per_word(bits)
    bm = min(block_m, max(8, M))      # ragged M is padded inside the kernel
    bk = min(block_k, K)
    # block_k must divide K and be a multiple of cpw
    while K % bk or bk % cpw:
        bk -= 1
    bn = min(block_n, N)
    while N % bn:
        bn -= 1

    y = quant_matmul_pallas(
        x2, words, alpha.astype(jnp.float32), beta.astype(jnp.float32),
        bits=bits, block_m=bm, block_n=bn, block_k=bk, interpret=interpret)
    if overflow_words is not None:
        cpw1 = packing.codes_per_word(1)
        bk1 = min(block_k, K)
        while K % bk1 or bk1 % cpw1:
            bk1 -= 1
        y_over = quant_matmul_pallas(
            x2, overflow_words, alpha.astype(jnp.float32),
            jnp.zeros_like(beta, jnp.float32),
            bits=1, block_m=bm, block_n=bn, block_k=bk1, interpret=interpret)
        y = y + y_over
    return y.reshape(lead + (N,)).astype(x.dtype)


def fused_quantize(w, *, bitwidths, parent_bits=8, extra_precision=False,
                   interpret: bool | None = None, vmem_budget=12 * 2**20):
    """All-precision fake-quantized planes of w: tuple, one per r."""
    if interpret is None:
        interpret = not _on_tpu()
    K, N = w.shape
    # choose block_n so the fp32 stripe fits the VMEM budget
    bn = 128
    while K * bn * 4 * (1 + len(bitwidths)) > vmem_budget and bn > 8:
        bn //= 2
    w_p, pad_n = _pad_to(w, bn, 1)
    outs = fused_quantize_pallas(
        w_p, bitwidths=tuple(bitwidths), parent_bits=parent_bits,
        extra_precision=extra_precision, block_n=bn, interpret=interpret)
    if pad_n:
        outs = tuple(o[:, :N] for o in outs)
    return outs


def plane_matmul(x, plane, *, bits: int, use_kernel: bool = False,
                 interpret: bool | None = None):
    """Bits-static entry point for a packed plane {'words','alpha','beta'}.

    The serving integration point: `models.common.qlinear` hands every
    packed weight plane here with the tier's bitwidth as a static int.
    K-packed planes route to the Pallas dequant-matmul kernel when
    `use_kernel` (TPU, or interpret mode elsewhere) and the plane tiles
    exactly; N-packed planes (down/wo projections, packed along the
    output dim so their reduction dim stays shardable) and non-tiling
    shapes take the jnp unpack twin -- identical math, so the two paths
    are interchangeable per-plane.

    x: (..., K); returns (..., N) in x.dtype (no bias).
    """
    words, alpha, beta = plane["words"], plane["alpha"], plane["beta"]
    K, N = x.shape[-1], alpha.shape[-1]
    cpw = packing.codes_per_word(bits)
    packed_k = words.shape[-2] != K        # else packed along N (down-type)
    if (use_kernel and packed_k and words.ndim == 2
            and words.shape[-2] * cpw == K):
        return quant_matmul(x, words, alpha, beta, bits=bits,
                            interpret=interpret)
    if packed_k:
        codes = packing.unpack_codes(words, bits, K, axis=-2)
    else:
        codes = packing.unpack_codes(words, bits, N, axis=-1)
    w_hat = (alpha * codes.astype(jnp.float32) - beta).astype(x.dtype)
    return x @ w_hat


def serve_linear(x, packed: packing.PackedLinear, bits: int,
                 extra_precision: bool = False, interpret: bool | None = None):
    """End-to-end packed serving linear: slice parent -> kernel matmul."""
    mat = packed.materialize(bits, extra_precision=extra_precision)
    if extra_precision:
        words, alpha, beta, over = mat
        return quant_matmul(x, words, alpha, beta, bits=bits,
                            overflow_words=over, interpret=interpret)
    words, alpha, beta = mat
    return quant_matmul(x, words, alpha, beta, bits=bits, interpret=interpret)


__all__ = ["quant_matmul", "plane_matmul", "fused_quantize", "serve_linear",
           "ref"]
