"""Pallas TPU kernel: fused Matryoshka paged decode attention.

The decode hot path of the paged KV cache (PR 7) used to gather every
page of a slot, dequantize the ENTIRE int8 store to a bf16 view in HBM
(`attention.gather_slot_view` -> `dequant_kv_rows`), and only then run
the grouped-einsum attend -- paying back the quantization byte saving
(x2-4 amplified) in per-step read traffic. This kernel attends
**directly from the page store**: per (slot, kv-head, page) tile it

  1. DMAs one page of uint8 parent codes (+ per-row fp32 alpha/beta),
     the physical page id resolved by the BLOCK INDEX MAP from the
     scalar-prefetched page table (indirection is data: page remaps
     never recompile, hole sentinels clamp to a masked dummy page),
  2. MSB-slices the r-bit attend view at the closure-static `kv_bits`
     on the parent grid (Eq. 4/6: int4/int2 read the SAME bytes -- the
     Matryoshka contract applied in-register),
  3. dequantizes with one alpha/beta FMA per (row, head) on the VPU,
  4. accumulates a flash-style online softmax (running max + rescaled
     sum in VMEM scratch) with per-slot length masking.

The (B, cache_len, kh, hd) bf16 view is never materialized; page
blocks past a slot's high-water position are skipped (`pl.when`), not
attended-then-masked. Hole pages (page id == num_pages) are always
past the high-water mark -- slots allocate pages contiguously -- so
the skip covers them; the index-map clamp only keeps the dummy DMA in
bounds. Grid order (slot, kv-head, page) keeps the page dim innermost
and sequential, so the VMEM scratch accumulator carries across pages
of one (slot, head) pair exactly like the K-innermost matmul grid.

On a (data, model) mesh kv_heads shard over 'model', so every tile's
page/scale reads stay shard-local and the kernel needs no cross-shard
traffic (the grid's kv-head dim simply shrinks per shard).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# Pages always store 8-bit parent codes; the attend view is an MSB
# slice (mirrors models.attention.KV_PARENT_BITS).
KV_PARENT_BITS = 8


def slice_dequant_tile(codes, alpha, beta, kv_bits: int):
    """fp32 rows of one page tile: in-register Matryoshka slice + FMA.

    codes: (page_size, hd) uint8 parent codes; alpha/beta: (page_size, 1)
    fp32 per-(row, head) scale/offset. The r-bit MSB slice runs on the
    PARENT grid -- `(2q + 2^(c-r)) >> (c-r+1)`, clamp, then `<< (c-r)`
    -- exactly `core.quant.slice_bits`, so the r-independent beta
    offsets apply unchanged and the result is bit-identical to
    `attention.dequant_kv_rows` at fp32 (the kernel-vs-gather oracle
    tests assert equality, not closeness).
    """
    q = codes.astype(jnp.int32)
    c, r = KV_PARENT_BITS, kv_bits
    if r != c:
        q = (2 * q + (1 << (c - r))) >> (c - r + 1)
        q = jnp.minimum(q, (1 << r) - 1)
        q = q << (c - r)        # back to the parent grid (Eq. 4/6)
    return alpha * q.astype(jnp.float32) - beta


def _online_softmax_block(q, k, v, start, pos, acc_ref, m_ref, l_ref,
                          scale):
    """Fold one page of keys/values into the running softmax state.

    q: (G, hd) fp32; k/v: (page_size, hd) fp32; start: first token
    index of this page; pos: the slot's current position (rows > pos
    masked). acc/m/l are VMEM scratch carried across the page grid dim:
    m the running row max, l the rescaled exp-sum, acc the rescaled
    weighted V accumulator -- the flash recurrence, finalized as acc/l.
    """
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    ki = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(ki <= pos, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _kernel_quant(ptab_ref, pos_ref, q_ref, kp_ref, ks_ref, kb_ref,
                  vp_ref, vs_ref, vb_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, kv_bits, page_size, scale):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[b]

    @pl.when(j * page_size <= pos)
    def _attend():
        k = slice_dequant_tile(kp_ref[0, :, 0, :], ks_ref[0], kb_ref[0],
                               kv_bits)
        v = slice_dequant_tile(vp_ref[0, :, 0, :], vs_ref[0], vb_ref[0],
                               kv_bits)
        _online_softmax_block(q_ref[0, 0].astype(jnp.float32), k, v,
                              j * page_size, pos, acc_ref, m_ref, l_ref,
                              scale)

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        # l >= exp(0): row 0 of page 0 is always visible (pos >= 0).
        o_ref[...] = (acc_ref[...] / l_ref[...]).reshape(o_ref.shape)


def _kernel_fp(ptab_ref, pos_ref, q_ref, kp_ref, vp_ref, o_ref, acc_ref,
               m_ref, l_ref, *, page_size, scale):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[b]

    @pl.when(j * page_size <= pos)
    def _attend():
        k = kp_ref[0, :, 0, :].astype(jnp.float32)
        v = vp_ref[0, :, 0, :].astype(jnp.float32)
        _online_softmax_block(q_ref[0, 0].astype(jnp.float32), k, v,
                              j * page_size, pos, acc_ref, m_ref, l_ref,
                              scale)

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] / l_ref[...]).reshape(o_ref.shape)


@functools.partial(jax.jit, static_argnames=("kv_bits", "interpret"))
def paged_attend_pallas(
    q: jax.Array,                 # (B, kh, G, hd) queries, kv-head-major
    ptab: jax.Array,              # (B, pages_per_slot) int32 page table
    pos: jax.Array,               # (B,) int32 per-slot write position
    kp: jax.Array,                # (P, page_size, kh, hd) codes / rows
    vp: jax.Array,
    ks: jax.Array | None = None,  # (P, page_size, kh) fp32 scale planes
    kb: jax.Array | None = None,
    vs: jax.Array | None = None,
    vb: jax.Array | None = None,
    *,
    kv_bits: int | None = None,   # static attend width (None: fp pages)
    interpret: bool = False,
) -> jax.Array:
    """Fused paged decode attention straight off the page store.

    Page id == P is the hole sentinel: the index map clamps it to P-1
    and the `j * page_size <= pos` skip guarantees the dummy tile is
    never folded in (holes only exist past the slot's high-water page).
    Returns fp32 (B, kh, G, hd) -- reshape to (B, 1, kh*G*hd) for the
    grouped-attend output layout of `attention._grouped_attend`.
    """
    B, kh, G, hd = q.shape
    P, page_size = kp.shape[0], kp.shape[1]
    pages_per_slot = ptab.shape[1]
    scale = hd ** -0.5
    quantized = ks is not None

    def q_map(b, h, j, ptab_ref, pos_ref):
        return (b, h, 0, 0)

    def page_map(b, h, j, ptab_ref, pos_ref):
        return (jnp.minimum(ptab_ref[b, j], P - 1), 0, h, 0)

    def scale_map(b, h, j, ptab_ref, pos_ref):
        return (jnp.minimum(ptab_ref[b, j], P - 1), 0, h)

    kv_spec = pl.BlockSpec((1, page_size, 1, hd), page_map)
    sc_spec = pl.BlockSpec((1, page_size, 1), scale_map)
    if quantized:
        in_specs = [pl.BlockSpec((1, 1, G, hd), q_map),
                    kv_spec, sc_spec, sc_spec, kv_spec, sc_spec, sc_spec]
        operands = (q, kp, ks, kb, vp, vs, vb)
        body = functools.partial(
            _kernel_quant,
            kv_bits=KV_PARENT_BITS if kv_bits is None else kv_bits,
            page_size=page_size, scale=scale)
    else:
        in_specs = [pl.BlockSpec((1, 1, G, hd), q_map), kv_spec, kv_spec]
        operands = (q, kp, vp)
        body = functools.partial(_kernel_fp, page_size=page_size,
                                 scale=scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, kh, pages_per_slot),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, hd), q_map),
        scratch_shapes=[pltpu.VMEM((G, hd), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32)],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, kh, G, hd), jnp.float32),
        interpret=interpret,
    )(ptab.astype(jnp.int32), pos.astype(jnp.int32), *operands)
