"""Pallas TPU kernel: packed-integer dequant matmul.

The serving hot-spot of MatQuant: decode-time FFN matmuls are HBM-
bandwidth-bound, so weights live in HBM as packed r-bit planes (int32
words, r in {1, 2, 4, 8}) and are expanded to bf16 only *after* the
HBM->VMEM DMA. Per (block_k, block_n) tile the kernel:

  1. DMAs the packed words (block_k / (32//bits), block_n) -- this is
     the 4x/8x/16x/32x byte saving vs bf16 weights,
  2. unpacks with vector shifts/masks (VPU),
  3. dequantizes  w = alpha * code - beta  (per-output-channel scales),
  4. feeds the MXU:  acc += x_tile @ w_tile  at fp32 accumulation.

Block shapes default to MXU-aligned (128, 128) tiles with K-innermost
grid order; the fp32 accumulator lives in the revisited output block.

Extra-Precision MatQuant (Errata Eq. 8): pass `overflow` (the 1-bit
packed bitmap plane, block (block_k/32, block_n)) and the kernel adds
the 2^bits-valued overflow term IN the dequant step -- full code =
base + 2^bits * bitmap -- so an ep tier costs one extra word DMA per
tile instead of a second kernel launch over the whole plane.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unpack_tile(words, bits):
    """Vector-op unpack of one packed word tile: (bkw, bn) -> (bk, bn)."""
    cpw = 32 // bits
    mask = (1 << bits) - 1
    # unpack: (bkw, bn) -> (bkw, cpw, bn) -> (bk, bn)
    shifts = (jnp.arange(cpw, dtype=jnp.int32) * bits)[None, :, None]
    codes = jax.lax.shift_right_logical(
        jnp.broadcast_to(words[:, None, :], (words.shape[0], cpw, words.shape[1])),
        jnp.broadcast_to(shifts, (words.shape[0], cpw, words.shape[1])),
    ) & mask
    return codes.reshape(words.shape[0] * cpw, words.shape[1])


def _dequant_tile(words, ovf_words, alpha, beta, bits, slice_bits=None,
                  slice_ep=False):
    """One tile's dequantized weights: alpha * code - beta, where code
    composes the base plane with the 2^bits-valued overflow bit.

    `slice_bits` (static) consumes an aliased draft view: the words are
    packed at the parent width `bits` and the Eq. 4/6 MSB slice to r =
    slice_bits runs here on the VPU, right after the unpack --
    `(2q + 2^(c-r)) >> (c-r+1)`, clamped to [0, 2^r - 1] unless
    `slice_ep` keeps the Errata Eq. 8 overflow bucket. Bit-identical to
    dequantizing a materialized r-bit plane (alpha carries the exact
    power-of-two grid re-scale), at zero extra plane bytes."""
    codes = _unpack_tile(words, bits)                # (bk, bn) int32
    if ovf_words is not None:
        codes = codes + (_unpack_tile(ovf_words, 1) << bits)
    if slice_bits is not None and slice_bits != bits:
        c, r = bits, slice_bits
        codes = (2 * codes + (1 << (c - r))) >> (c - r + 1)
        if not slice_ep:
            codes = jnp.minimum(codes, (1 << r) - 1)
    return alpha * codes.astype(jnp.float32) - beta


def _kernel(x_ref, w_ref, alpha_ref, beta_ref, o_ref, *, bits, k_steps,
            slice_bits=None, slice_ep=False):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _dequant_tile(w_ref[...], None, alpha_ref[...], beta_ref[...], bits,
                      slice_bits, slice_ep)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


def _kernel_ep(x_ref, w_ref, ovf_ref, alpha_ref, beta_ref, o_ref, *, bits,
               k_steps):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _dequant_tile(w_ref[...], ovf_ref[...], alpha_ref[...], beta_ref[...],
                      bits)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "block_m", "block_n", "block_k", "interpret",
                     "slice_bits", "slice_ep"),
)
def quant_matmul_pallas(
    x: jax.Array,            # (M, K) float
    words: jax.Array,        # (K // cpw, N) int32 packed codes
    alpha: jax.Array,        # (1, N) f32
    beta: jax.Array,         # (1, N) f32   (beta = alpha * zero_point)
    overflow: jax.Array | None = None,   # (K // 32, N) int32 1-bit bitmap
    *,
    bits: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
    slice_bits: int | None = None,   # static: on-the-fly MSB slice width
    slice_ep: bool = False,          # static: slice without clamp (Eq. 8)
) -> jax.Array:
    if slice_bits is not None:
        assert overflow is None, "sliced views carry no overflow bitmap"
    M, K = x.shape
    cpw = 32 // bits
    Kw, N = words.shape
    assert Kw * cpw == K, (Kw, cpw, K)
    # M is ragged in serving (decode batches are rarely multiples of
    # 128): pad the activation rows up to block_m and slice the product
    # back. K/N come from the packed weight planes and must tile exactly.
    assert N % block_n == 0 and K % block_k == 0, (
        N, K, block_n, block_k)
    assert block_k % cpw == 0
    if overflow is not None:
        assert overflow.shape == (K // 32, N), (overflow.shape, K, N)
        assert block_k % 32 == 0, block_k   # the bitmap tile must be whole
    pad_m = (-M) % block_m
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    k_steps = K // block_k
    grid = ((M + pad_m) // block_m, N // block_n, k_steps)

    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
        pl.BlockSpec((block_k // cpw, block_n), lambda i, j, k: (k, j)),
    ]
    operands = [x, words]
    if overflow is not None:
        in_specs.append(
            pl.BlockSpec((block_k // 32, block_n), lambda i, j, k: (k, j)))
        operands.append(overflow)
    in_specs += [
        pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
    ]
    operands += [alpha, beta]
    if overflow is not None:
        body = functools.partial(_kernel_ep, bits=bits, k_steps=k_steps)
    else:
        body = functools.partial(_kernel, bits=bits, k_steps=k_steps,
                                 slice_bits=slice_bits, slice_ep=slice_ep)

    out = pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M + pad_m, N), jnp.float32),
        interpret=interpret,
    )(*operands)
    if pad_m:
        out = out[:M]
    return out.astype(x.dtype)


def _kernel_experts(x_ref, w_ref, alpha_ref, beta_ref, o_ref, *, bits,
                    slice_bits=None, slice_ep=False):
    """`_kernel` with a leading expert grid dim (blocks carry E=1)."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _dequant_tile(w_ref[0], None, alpha_ref[0], beta_ref[0], bits,
                      slice_bits, slice_ep)
    x = x_ref[0].astype(jnp.float32)
    o_ref[0, :, :] += jnp.dot(x, w, preferred_element_type=jnp.float32)


def _kernel_experts_ep(x_ref, w_ref, ovf_ref, alpha_ref, beta_ref, o_ref, *,
                       bits):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _dequant_tile(w_ref[0], ovf_ref[0], alpha_ref[0], beta_ref[0], bits)
    x = x_ref[0].astype(jnp.float32)
    o_ref[0, :, :] += jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "block_m", "block_n", "block_k", "interpret",
                     "slice_bits", "slice_ep"),
)
def quant_matmul_experts_pallas(
    x: jax.Array,            # (E, M, K) float
    words: jax.Array,        # (E, K // cpw, N) int32 packed codes
    alpha: jax.Array,        # (E, 1, N) f32
    beta: jax.Array,         # (E, 1, N) f32
    overflow: jax.Array | None = None,   # (E, K // 32, N) 1-bit bitmap
    *,
    bits: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
    slice_bits: int | None = None,   # static: on-the-fly MSB slice width
    slice_ep: bool = False,          # static: slice without clamp (Eq. 8)
) -> jax.Array:
    """Batched-over-experts `quant_matmul_pallas`: one packed plane per
    expert of a MoE stack, the grid extended with a leading E dim so
    every (expert, tile) pair is one kernel instance. Same per-tile
    math as the 2-D kernel (DMA packed words, VPU unpack, MXU matmul),
    including the in-kernel 2^bits-valued overflow term when the
    expert stack carries an extra-precision bitmap."""
    if slice_bits is not None:
        assert overflow is None, "sliced views carry no overflow bitmap"
    E, M, K = x.shape
    cpw = 32 // bits
    Ew, Kw, N = words.shape
    assert Ew == E and Kw * cpw == K, (Ew, E, Kw, cpw, K)
    assert N % block_n == 0 and K % block_k == 0, (N, K, block_n, block_k)
    assert block_k % cpw == 0
    if overflow is not None:
        assert overflow.shape == (E, K // 32, N), (overflow.shape, E, K, N)
        assert block_k % 32 == 0, block_k
    pad_m = (-M) % block_m
    if pad_m:
        x = jnp.pad(x, ((0, 0), (0, pad_m), (0, 0)))
    grid = (E, (M + pad_m) // block_m, N // block_n, K // block_k)

    in_specs = [
        pl.BlockSpec((1, block_m, block_k), lambda e, i, j, k: (e, i, k)),
        pl.BlockSpec((1, block_k // cpw, block_n),
                     lambda e, i, j, k: (e, k, j)),
    ]
    operands = [x, words]
    if overflow is not None:
        in_specs.append(pl.BlockSpec((1, block_k // 32, block_n),
                                     lambda e, i, j, k: (e, k, j)))
        operands.append(overflow)
    in_specs += [
        pl.BlockSpec((1, 1, block_n), lambda e, i, j, k: (e, 0, j)),
        pl.BlockSpec((1, 1, block_n), lambda e, i, j, k: (e, 0, j)),
    ]
    operands += [alpha, beta]
    if overflow is not None:
        body = functools.partial(_kernel_experts_ep, bits=bits)
    else:
        body = functools.partial(_kernel_experts, bits=bits,
                                 slice_bits=slice_bits, slice_ep=slice_ep)

    out = pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_m, block_n),
                               lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, M + pad_m, N), jnp.float32),
        interpret=interpret,
    )(*operands)
    if pad_m:
        out = out[:, :M]
    return out.astype(x.dtype)
