"""Pure-jnp oracles for every kernel (the correctness ground truth).

Each ref mirrors the kernel contract bit-for-bit; kernel tests sweep
shapes/dtypes/bits and assert_allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing, quant


def quant_matmul_ref(x, words, alpha, beta, *, bits: int):
    """x: (M, K); words: (K//cpw, N) int32; alpha,beta: (1, N)."""
    K = x.shape[1]
    codes = packing.unpack_codes(words, bits, K, axis=0)      # (K, N)
    w = alpha * codes.astype(jnp.float32) - beta
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def quant_matmul_ep_ref(x, words, alpha, beta, overflow_words, *, bits: int):
    """Extra-Precision variant: the base plane keeps the low `bits` bits
    of the [0, 2^bits] sliced code; the 1-bit bitmap plane is bit `bits`
    (the overflow bucket), so value = alpha * (base + 2^bits * bitmap)
    - beta -- the decomposition the kernels compose in-tile."""
    K = x.shape[1]
    codes = packing.unpack_codes(words, bits, K, axis=0).astype(jnp.float32)
    over = packing.unpack_codes(overflow_words, 1, K, axis=0).astype(jnp.float32)
    w = alpha * (codes + float(2**bits) * over) - beta
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def fused_quantize_ref(w, *, bitwidths, parent_bits: int = 8,
                       extra_precision: bool = False):
    """Per-output-channel (axis=0 groups) quantize + slice for all r."""
    return tuple(
        quant.quant_dequant(w, parent_bits, r, axis=0,
                            extra_precision=extra_precision).astype(w.dtype)
        for r in bitwidths
    )


def paged_attend_ref(q, ptab, pos, kp, vp, ks=None, kb=None, vs=None,
                     vb=None, *, kv_bits=None):
    """Dense oracle for the fused paged-attention kernel: gather every
    page through the table (holes fill zeros), dequantize the r-bit MSB
    view of the whole slot, and run a DENSE masked softmax -- the exact
    math the online-softmax recurrence must reproduce. q: (B, kh, G,
    hd); returns fp32 (B, kh, G, hd)."""
    from repro.kernels.paged_attention import KV_PARENT_BITS, NEG_INF

    B, kh, G, hd = q.shape
    page_size = kp.shape[1]
    rows = ptab.shape[1] * page_size

    def gather(a):
        g = jnp.take(a, ptab, axis=0, mode="fill", fill_value=0)
        return g.reshape((B, rows) + a.shape[2:])

    if ks is None:
        k = gather(kp).astype(jnp.float32)
        v = gather(vp).astype(jnp.float32)
    else:
        bits = KV_PARENT_BITS if kv_bits is None else kv_bits

        def deq(codes, alpha, beta):
            grid = quant.slice_bits(codes.astype(jnp.int32),
                                    KV_PARENT_BITS, bits)
            return (alpha[..., None] * grid.astype(jnp.float32)
                    - beta[..., None])

        k = deq(gather(kp), gather(ks), gather(kb))
        v = deq(gather(vp), gather(vs), gather(vb))
    s = jnp.einsum("bhgd,bkhd->bhgk", q.astype(jnp.float32), k,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    mask = jnp.arange(rows)[None, None, None, :] <= pos[:, None, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgk,bkhd->bhgd", p, v,
                      preferred_element_type=jnp.float32)
