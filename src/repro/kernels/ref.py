"""Pure-jnp oracles for every kernel (the correctness ground truth).

Each ref mirrors the kernel contract bit-for-bit; kernel tests sweep
shapes/dtypes/bits and assert_allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing, quant


def quant_matmul_ref(x, words, alpha, beta, *, bits: int):
    """x: (M, K); words: (K//cpw, N) int32; alpha,beta: (1, N)."""
    K = x.shape[1]
    codes = packing.unpack_codes(words, bits, K, axis=0)      # (K, N)
    w = alpha * codes.astype(jnp.float32) - beta
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def quant_matmul_ep_ref(x, words, alpha, beta, overflow_words, *, bits: int):
    """Extra-Precision variant: the base plane keeps the low `bits` bits
    of the [0, 2^bits] sliced code; the 1-bit bitmap plane is bit `bits`
    (the overflow bucket), so value = alpha * (base + 2^bits * bitmap)
    - beta -- the decomposition the kernels compose in-tile."""
    K = x.shape[1]
    codes = packing.unpack_codes(words, bits, K, axis=0).astype(jnp.float32)
    over = packing.unpack_codes(overflow_words, 1, K, axis=0).astype(jnp.float32)
    w = alpha * (codes + float(2**bits) * over) - beta
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def fused_quantize_ref(w, *, bitwidths, parent_bits: int = 8,
                       extra_precision: bool = False):
    """Per-output-channel (axis=0 groups) quantize + slice for all r."""
    return tuple(
        quant.quant_dequant(w, parent_bits, r, axis=0,
                            extra_precision=extra_precision).astype(w.dtype)
        for r in bitwidths
    )
