import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count at first init). 512 placeholder host devices back the
# 16x16 single-pod and 2x16x16 multi-pod production meshes.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell.

For each cell this:
  1. builds the production mesh (16x16 or 2x16x16),
  2. constructs the real step function (MatQuant QAT train_step for
     train shapes; prefill / decode serve steps otherwise),
  3. resolves NamedShardings for params / optimizer / batch / caches
     from the logical-axis rules,
  4. jit-lowers with ShapeDtypeStructs (zero allocation), compiles,
  5. records memory_analysis(), cost_analysis(), and the collective
     schedule parsed from the compiled HLO.

Usage:
  python -m repro.launch.dryrun --arch qwen3_8b --shape train_4k
      [--multi-pod] [--layers N] [--unroll] [--microbatches M]
      [--json out.json] [--print-hlo]
"""

import argparse
import json
import re
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, input_specs, shape_skips
from repro.launch.mesh import make_production_mesh
from repro.models import api, common as cm
from repro.optim import OptConfig, adamw_init
from repro.runtime import sharding as shard
from repro.train import make_train_step

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def parse_collectives(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the HLO."""
    totals = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) (all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)", stripped)
        if not m:
            continue
        shapes_str, kind = m.group(1), m.group(2)
        if kind + "-start" in stripped or kind + "-done" in stripped:
            pass  # shapes identical; count once via the -start form
        nbytes = 0
        for dt, dims in shape_re.findall(shapes_str):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        totals[kind]["count"] += 1
        totals[kind]["bytes"] += nbytes
    totals["total_bytes"] = sum(v["bytes"] for k, v in totals.items()
                                if isinstance(v, dict))
    return totals


def microbatch_count(cfg, shape, mesh) -> int:
    """Pick grad-accum microbatches so the remat stash fits ~4 GB/dev."""
    sizes = shard.mesh_axis_sizes(mesh)
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    per_dev = max(shape.global_batch // dp, 1)
    n_prec = max(len(cfg.quant.bitwidths), 1)
    stash = cfg.num_layers * per_dev * shape.seq_len * cfg.d_model * 2 * n_prec
    budget = 4 * 2**30
    need = max(1, -(-stash // budget))
    mb = 1
    while mb < need and mb < shape.global_batch:
        mb *= 2
    while shape.global_batch % mb:
        mb //= 2
    return max(mb, 1)


def build_cell(arch: str, shape_name: str, mesh, *, layers=None,
               unroll=False, microbatches=None, serve_bits=None,
               packed_bits: int = 0, remat: str = '', vmap_precisions=False):
    """Returns (lowered, meta) for one (arch x shape) cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = shape_skips(cfg).get(shape_name)
    if skip:
        raise SystemExit(f"SKIP {arch} x {shape_name}: {skip}")
    if layers:
        repl = {"num_layers": layers}
        if cfg.encoder_layers:
            repl["encoder_layers"] = layers
        cfg = cfg.replace(**repl)
    if unroll:
        cfg = cfg.replace(unroll_layers=True)
    if remat:
        cfg = cfg.replace(remat=remat)
    if packed_bits:
        import dataclasses as _dc
        cfg = cfg.replace(quant=_dc.replace(cfg.quant, packed_bits=packed_bits))

    cm.set_act_resolver(shard.make_act_resolver(mesh))
    key = jax.random.PRNGKey(0)
    # serve cells use TP-only weight rules (no per-step FSDP gathers)
    rules = shard.RULES if shape.kind == "train" else shard.serving_rules()
    if packed_bits and shape.kind != "train":
        from repro.serve.engine import materialize_packed_params, packed_axes
        params_spec = jax.eval_shape(
            lambda k: materialize_packed_params(api.init(k, cfg), cfg,
                                                packed_bits), key)
        p_axes = packed_axes(api.axes(cfg), params_spec, cfg)
    else:
        params_spec = jax.eval_shape(partial(api.init, cfg=cfg), key)
        p_axes = api.axes(cfg)
    params_sh = shard.tree_shardings(p_axes, params_spec, mesh, rules)
    batch_spec = input_specs(cfg, shape)
    batch_sh = shard.batch_shardings(batch_spec, mesh)

    if shape.kind == "train":
        mb = microbatches or microbatch_count(cfg, shape, mesh)
        opt_cfg = OptConfig()
        step = make_train_step(cfg, opt_cfg, microbatches=mb,
                               vmap_precisions=vmap_precisions)
        opt_spec = jax.eval_shape(adamw_init, params_spec)
        opt_sh = {"m": params_sh, "v": params_sh,
                  "step": jax.NamedSharding(mesh, jax.sharding.PartitionSpec())}
        lowered = jax.jit(
            step,
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, None),
            donate_argnums=(0, 1),
        ).lower(params_spec, opt_spec, batch_spec)
        meta = {"kind": "train", "microbatches": mb}
    elif shape.kind == "prefill":
        fn = lambda params, batch: api.prefill(
            params, batch, cfg, bits=serve_bits, max_len=shape.seq_len)
        state_spec = jax.eval_shape(
            partial(api.init_state, cfg, shape.global_batch, shape.seq_len))
        state_sh = shard.tree_shardings(api.state_axes(cfg), state_spec, mesh, rules)
        lowered = jax.jit(
            fn, in_shardings=(params_sh, batch_sh),
            out_shardings=(None, state_sh),
        ).lower(params_spec, batch_spec)
        meta = {"kind": "prefill"}
    else:  # decode
        state_spec = jax.eval_shape(
            partial(api.init_state, cfg, shape.global_batch, shape.seq_len))
        state_sh = shard.tree_shardings(api.state_axes(cfg), state_spec, mesh, rules)
        fn = lambda params, state, token, pos: api.decode_step(
            params, state, token, pos, cfg, bits=serve_bits)
        lowered = jax.jit(
            fn,
            in_shardings=(params_sh, state_sh, batch_sh["token"], batch_sh["pos"]),
            out_shardings=(None, state_sh),
            donate_argnums=(1,),
        ).lower(params_spec, state_spec,
                batch_spec["token"], batch_spec["pos"])
        meta = {"kind": "decode"}
    meta.update(arch=arch, shape=shape_name, layers=cfg.num_layers,
                family=cfg.family, params=cfg.param_count(),
                active_params=cfg.active_param_count())
    return lowered, meta


def run_cell(arch, shape_name, *, multi_pod=False, layers=None, unroll=False,
             microbatches=None, serve_bits=None, packed_bits=0, remat='',
             vmap_precisions=False, print_hlo=False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered, meta = build_cell(arch, shape_name, mesh, layers=layers,
                               unroll=unroll, microbatches=microbatches,
                               serve_bits=serve_bits, packed_bits=packed_bits,
                               remat=remat, vmap_precisions=vmap_precisions)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):     # JAX <= 0.4.x: one dict per program
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    result = {
        **meta,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(jax.device_count()) if multi_pod else 256,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
        },
        "cost": {
            "flops": ca.get("flops"),
            "bytes_accessed": ca.get("bytes accessed"),
            "transcendentals": ca.get("transcendentals"),
        },
        "collectives": colls,
    }
    if print_hlo:
        print(hlo)
    return result


def _extrap_depths(cfg) -> tuple[int, int]:
    """Depths for the two shallow unrolled cost runs. Hybrid archs use
    multiples of attn_period so the per-layer slope amortizes exactly
    one shared-attention application per period."""
    if cfg.family == "hybrid" and cfg.attn_period:
        return cfg.attn_period, 2 * cfg.attn_period
    return 2, 4


def run_cell_extrapolated(arch, shape_name, *, multi_pod=False,
                          serve_bits=None, microbatches=None, packed_bits=0,
                          remat='', vmap_precisions=False):
    """Full-depth compile (memory + collective schedule + proof) plus two
    shallow *unrolled* compiles to recover per-layer FLOPs/bytes that
    XLA's cost_analysis hides inside while-loop bodies (counted once).

    corrected(L) = shallow(l1) + (L - l1) * [shallow(l2)-shallow(l1)]/(l2-l1)
    Shallow runs use microbatches=1 (the grad-accum scan body is also
    counted once), so corrected terms are per-full-batch; see §Roofline
    notes in EXPERIMENTS.md.
    """
    cfg = get_config(arch)
    full = run_cell(arch, shape_name, multi_pod=multi_pod, serve_bits=serve_bits,
                    microbatches=microbatches, packed_bits=packed_bits, remat=remat,
                    vmap_precisions=vmap_precisions)
    l1, l2 = _extrap_depths(cfg)
    lo = run_cell(arch, shape_name, multi_pod=multi_pod, layers=l1, unroll=True,
                  microbatches=1, serve_bits=serve_bits, packed_bits=packed_bits,
                  remat=remat, vmap_precisions=vmap_precisions)
    hi = run_cell(arch, shape_name, multi_pod=multi_pod, layers=l2, unroll=True,
                  microbatches=1, serve_bits=serve_bits, packed_bits=packed_bits,
                  remat=remat, vmap_precisions=vmap_precisions)
    L = cfg.num_layers

    def lin(a, b):
        if a is None or b is None:
            return None
        slope = (b - a) / (l2 - l1)
        return a + (L - l1) * slope

    corrected = {
        "flops": lin(lo["cost"]["flops"], hi["cost"]["flops"]),
        "bytes_accessed": lin(lo["cost"]["bytes_accessed"],
                              hi["cost"]["bytes_accessed"]),
        "collective_bytes": lin(lo["collectives"]["total_bytes"],
                                hi["collectives"]["total_bytes"]),
        "per_layer_flops": (hi["cost"]["flops"] - lo["cost"]["flops"]) / (l2 - l1),
        "depths": [l1, l2, L],
    }
    full["corrected"] = corrected
    full["shallow"] = {"lo": lo, "hi": hi}
    return full


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--layers", type=int, default=None,
                    help="override depth (roofline extrapolation runs)")
    ap.add_argument("--unroll", action="store_true",
                    help="python-unroll layers so cost_analysis counts them")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--serve-bits", type=int, default=None)
    ap.add_argument("--packed-bits", type=int, default=0,
                    help="serve weights as packed r-bit planes")
    ap.add_argument("--remat", default="", choices=["", "block", "dots"])
    ap.add_argument("--vmap-precisions", action="store_true")
    ap.add_argument("--extrapolate", action="store_true",
                    help="full compile + 2 shallow unrolled cost runs")
    ap.add_argument("--json", default=None)
    ap.add_argument("--print-hlo", action="store_true")
    args = ap.parse_args()

    assert jax.device_count() == 512, jax.device_count()
    if args.extrapolate:
        result = run_cell_extrapolated(
            args.arch, args.shape, multi_pod=args.multi_pod,
            serve_bits=args.serve_bits, microbatches=args.microbatches,
            packed_bits=args.packed_bits, remat=args.remat,
            vmap_precisions=args.vmap_precisions)
    else:
        result = run_cell(
            args.arch, args.shape, multi_pod=args.multi_pod, layers=args.layers,
            unroll=args.unroll, microbatches=args.microbatches,
            serve_bits=args.serve_bits, packed_bits=args.packed_bits,
            remat=args.remat, print_hlo=args.print_hlo)
    print(json.dumps({k: v for k, v in result.items() if k != "shallow"},
                     indent=1))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f)
    return 0


if __name__ == "__main__":
    sys.exit(main())
