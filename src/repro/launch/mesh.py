"""Production mesh construction (pure function -- importing this module
never touches jax device state)."""

from __future__ import annotations

from repro.runtime.sharding import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever-is-available mesh for tests/examples (elastic): uses all
    local devices, model_parallel innermost.

    The degenerate 1-device mesh (1 device, model_parallel=1) is valid
    on purpose: single-device serving goes through the exact same mesh
    placement code as a real TP deployment, just with every
    NamedSharding resolving to one shard.
    """
    import jax

    n = len(jax.devices())
    if model_parallel < 1 or n % model_parallel != 0:
        raise ValueError(
            f"make_host_mesh: cannot fold {n} local device(s) into a "
            f"(data, model={model_parallel}) mesh -- model_parallel must be "
            f">= 1 and divide the device count. On a CPU-only host, force "
            f"more devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N "
            f"(set it in the environment BEFORE jax is imported; "
            f"`make test-shard` does this for the sharded serving tests).")
    return make_mesh((n // model_parallel, model_parallel), ("data", "model"))


def make_replica_meshes(num_replicas: int, model_parallel: int = 1):
    """Split the local devices into `num_replicas` disjoint (data, model)
    meshes -- one per fleet replica (serve/fleet.py).

    With fewer devices than replicas (e.g. the plain single-CPU test
    environment), replicas SHARE devices round-robin over degenerate
    1-device meshes instead of failing: the fleet is then correct but
    not parallel, which is exactly what the device-count-agnostic
    tests want. Under the forced-host idiom
    (XLA_FLAGS=--xla_force_host_platform_device_count=8) every replica
    gets its own device subset and steps overlap via async dispatch.
    """
    import numpy as np
    import jax
    from jax.sharding import Mesh

    if num_replicas < 1:
        raise ValueError("make_replica_meshes: num_replicas must be >= 1")
    devs = jax.devices()
    n = len(devs)
    if n < num_replicas * model_parallel:
        if model_parallel > 1:
            raise ValueError(
                f"make_replica_meshes: {n} device(s) cannot give "
                f"{num_replicas} replicas model_parallel={model_parallel} "
                f"each (need {num_replicas * model_parallel})")
        return [Mesh(np.array([devs[i % n]]).reshape(1, 1),
                     ("data", "model"))
                for i in range(num_replicas)]
    per = n // num_replicas
    per -= per % model_parallel          # whole TP groups per replica
    return [Mesh(np.array(devs[i * per:(i + 1) * per]).reshape(
                     per // model_parallel, model_parallel),
                 ("data", "model"))
            for i in range(num_replicas)]
