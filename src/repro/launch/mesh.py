"""Production mesh construction (pure function -- importing this module
never touches jax device state)."""

from __future__ import annotations

from repro.runtime.sharding import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever-is-available mesh for tests/examples (elastic): uses all
    local devices, model_parallel innermost."""
    import jax

    n = len(jax.devices())
    assert n % model_parallel == 0, (n, model_parallel)
    return make_mesh((n // model_parallel, model_parallel), ("data", "model"))
