"""Roofline aggregation: dry-run JSONs -> per-cell terms + markdown.

Terms (per the methodology; all PER-DEVICE, matching the SPMD module
that cost_analysis reports on):

  compute term    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16)
  memory term     = HLO_bytes / HBM_bw                (819 GB/s)
  collective term = collective_bytes / link_bw        (50 GB/s/link)

HLO_FLOPs / HLO_bytes / collective_bytes use the depth-extrapolated
values (XLA counts while-loop bodies once; see dryrun.run_cell_extrapolated).
HLO_bytes is an UNFUSED upper bound (every op's operands+outputs); the
table also reports an analytic HBM floor (weights + boundary
activations + optimizer streams) for the bottleneck discussion.

MODEL_FLOPS = 6*N*D (train; x len(R) for MatQuant's multi-precision
objective) or 2*N*D (serve), N = active params, D = tokens.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

N_PRECISIONS = 3  # MatQuant default R = {8, 4, 2}


def model_flops(rec) -> tuple[float, float]:
    """(one-precision, matquant) global model FLOPs for the cell."""
    n = rec["active_params"]
    shape = rec["shape"]
    kind = rec["kind"]
    seq = {"train_4k": 4096, "prefill_32k": 32768,
           "decode_32k": 1, "long_500k": 1}[shape]
    batch = {"train_4k": 256, "prefill_32k": 32,
             "decode_32k": 128, "long_500k": 1}[shape]
    tokens = seq * batch
    if kind == "train":
        one = 6.0 * n * tokens
        return one, one * N_PRECISIONS
    return 2.0 * n * tokens, 2.0 * n * tokens


def analytic_hbm_bytes(rec) -> float:
    """Per-device HBM floor: params stream + optimizer + boundary acts."""
    chips = rec.get("chips", 256)
    n = rec["params"]
    kind = rec["kind"]
    mb = rec.get("microbatches", 1)
    shape = rec["shape"]
    seq = {"train_4k": 4096, "prefill_32k": 32768,
           "decode_32k": 1, "long_500k": 1}[shape]
    batch = {"train_4k": 256, "prefill_32k": 32,
             "decode_32k": 128, "long_500k": 1}[shape]
    d_bytes = 2
    if kind == "train":
        # per microbatch: read w (x3 precisions fwd + bwd recompute), write grads
        w_stream = n * d_bytes * mb * (N_PRECISIONS * 2 + 1) / chips
        opt = n * (4 * 4) / chips          # m, v read+write fp32
        acts = rec["layers"] * batch * seq * 2048 * d_bytes * 4 / chips
        return w_stream + opt + acts
    if kind == "prefill":
        return (n * d_bytes + rec["layers"] * batch * seq * 2048 * d_bytes) / chips
    # decode: weights + KV/state read dominate
    mem = rec.get("memory") or {}
    cache = (mem.get("argument_bytes") or 0)
    return n * d_bytes / chips + cache


def terms(rec) -> dict:
    cor = rec.get("corrected") or {}
    flops = cor.get("flops") or (rec.get("cost") or {}).get("flops") or 0
    byts = cor.get("bytes_accessed") or (rec.get("cost") or {}).get("bytes_accessed") or 0
    coll = cor.get("collective_bytes")
    if coll is None:
        coll = (rec.get("collectives") or {}).get("total_bytes", 0)
    chips = rec.get("chips", 256)
    one_mf, mat_mf = model_flops(rec)
    t = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": byts / HBM_BW,
        "collective_s": coll / LINK_BW,
        "analytic_mem_s": analytic_hbm_bytes(rec) / HBM_BW,
        "model_flops_1p": one_mf,
        "model_flops_mq": mat_mf,
        "useful_ratio_1p": (one_mf / chips) / flops if flops else 0.0,
        "useful_ratio_mq": (mat_mf / chips) / flops if flops else 0.0,
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: t[k])
    t["dominant"] = dom.replace("_s", "")
    # roofline fraction: useful compute time / the binding term
    binding = max(t["compute_s"], t["memory_s"], t["collective_s"])
    t["roofline_fraction"] = ((mat_mf / chips) / PEAK_FLOPS) / binding if binding else 0.0
    return t


def load(dirpath: str, mesh: str = "single"):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirpath, f"*__{mesh}.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def markdown(recs) -> str:
    lines = [
        "| arch | shape | kind | compute s | memory s (HLO ub) | mem s (analytic) | collective s | dominant | useful/HLO (MQ) | roofline frac | mem/dev GB | compile s |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("skipped"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | SKIP | — | — | — | — |")
            continue
        t = terms(r)
        mem = r.get("memory") or {}
        dev_gb = ((mem.get("argument_bytes") or 0) +
                  (mem.get("temp_bytes") or 0)) / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {t['compute_s']:.3g} | {t['memory_s']:.3g} "
            f"| {t['analytic_mem_s']:.3g} | {t['collective_s']:.3g} "
            f"| **{t['dominant']}** | {t['useful_ratio_mq']:.2f} "
            f"| {t['roofline_fraction']:.3f} | {dev_gb:.1f} "
            f"| {r.get('compile_s', 0):.0f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    recs = load(args.dir, args.mesh)
    md = markdown(recs)
    print(md)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md + "\n")


if __name__ == "__main__":
    main()
