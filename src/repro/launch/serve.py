"""Serving driver: a Poisson arrival stream through continuous batching.

Demonstrates the paper's deployment story (Section 5.4) as a *runtime*
behavior: one int8 parent checkpoint; requests arrive as an open-loop
Poisson process, the continuous-batching scheduler admits them into KV
slots as capacity frees up, and (with --elastic) the precision router
downgrades int8 -> int4 -> Mix'n'Match -> int2+ep -> int2 while the
queue is deep and recovers when it drains. See docs/serving.md for the
full operator guide (every flag, the tier ladder, and how to read
BENCH_serve.json).

  # elastic precision under load
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b --reduced \
      --elastic --requests 32 --arrival-rate 16 --prompt-len 24 --gen-tokens 12

  # fixed tier, legacy fixed-batch loop
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b --reduced \
      --bits 2 --legacy --requests 8 --prompt-len 32 --gen-tokens 16

  # 4-replica fleet behind one global elastic router
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b --reduced \
      --replicas 4 --requests 32 --arrival-rate 16
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import mixnmatch
from repro.data import DataConfig, SyntheticCorpus
from repro.models import api
from repro.serve import Engine, ServeConfig, SpecDecodeConfig
from repro.serve.scheduler import poisson_trace


def parse_draft_tier(name: str) -> tuple[int, bool]:
    """'int2' / 'int4' / 'int2+ep' -> (bits, extra_precision)."""
    base, _, suffix = name.partition("+")
    if not base.startswith("int") or not base[3:].isdigit() or suffix not in ("", "ep"):
        raise ValueError(f"--draft-tier {name!r}: expected intN or intN+ep")
    return int(base[3:]), suffix == "ep"


def build_engine(args, cfg):
    mesh = None
    if args.model_parallel:
        from repro.launch.mesh import make_host_mesh
        from repro.runtime.sharding import mesh_axis_sizes
        mesh = make_host_mesh(args.model_parallel)
        print(f"serving on a {mesh_axis_sizes(mesh)} host mesh")
    params = api.init(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt:
        from repro.runtime.checkpoint import CheckpointManager
        mgr = CheckpointManager(args.ckpt)
        state = mgr.restore({"params": params})
        if state is not None:
            params = state["params"]
            print(f"loaded checkpoint from {args.ckpt}")

    if args.mixnmatch_bits is not None:
        bits = mixnmatch.assign(cfg.num_layers, args.mixnmatch_bits, "pyramid")
        eff = mixnmatch.effective_bits(bits)
        print(f"mix'n'match pyramid assignment ({eff:.2f} eff bits): {bits}")
    else:
        bits = args.bits
    kv_bits = None
    if args.kv_bits and args.kv_bits != "dense":
        kv_bits = args.kv_bits if args.kv_bits in ("fp", "auto") \
            else int(args.kv_bits)
    return Engine(params, cfg, ServeConfig(
        bits=bits, max_len=args.prompt_len + args.gen_tokens,
        extra_precision=args.extra_precision, use_packed=args.packed,
        num_slots=args.num_slots, page_size=args.page_size,
        kv_bits=kv_bits, kv_page_size=args.kv_page_size or None,
        prefix_cache=args.prefix_cache,
        attn_kernel=args.attn_kernel), mesh=mesh)


def build_trace(args, cfg):
    return poisson_trace(cfg, requests=args.requests,
                         prompt_len=args.prompt_len,
                         gen_tokens=args.gen_tokens,
                         rate=args.arrival_rate, seed=args.seed)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--mixnmatch-bits", type=float, default=None,
                    help="effective-bits budget; overrides --bits")
    ap.add_argument("--extra-precision", action="store_true",
                    help="Errata Eq. 8 overflow bucket: serve every tier "
                         "with the 1-bit overflow bitmap on top of its "
                         "base bits (~+0.05 Table-7 effective bits); "
                         "composes with --packed (the bitmap rides the "
                         "plane into the kernel). The elastic ladder "
                         "always carries an int2+ep rung regardless")
    ap.add_argument("--packed", action="store_true",
                    help="serve packed r-bit planes (Pallas kernel on TPU, "
                         "jnp twin elsewhere); with --elastic, every "
                         "tier -- uniform, Mix'n'Match, extra-precision "
                         "-- becomes packed planes so a downgrade cuts "
                         "HBM weight bytes per step")
    ap.add_argument("--model-parallel", type=int, default=0,
                    help="serve on a (data, model) host mesh built from all "
                         "local devices with this model-parallel degree: "
                         "packed tier planes shard over 'model' (per-device "
                         "plane bytes divide by it), KV slots shard over "
                         "'data'. 0 (default) keeps the single-device path; "
                         "1 runs the degenerate 1-device mesh through the "
                         "same sharded code. On CPU, force devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--arrival-rate", type=float, default=8.0,
                    help="Poisson arrivals per second")
    ap.add_argument("--num-slots", type=int, default=4,
                    help="concurrent decode slots (continuous batching)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--kv-bits", default="dense",
                    choices=["dense", "fp", "8", "4", "2", "auto"],
                    help="paged KV cache: 'dense' (default) keeps the "
                         "per-slot slot-array state; 'fp' pages the cache "
                         "at model dtype (token-identical to dense); 8/4/2 "
                         "store int8 Matryoshka pages attended at that "
                         "sliced width; 'auto' ties the KV read width to "
                         "the served weight tier (int2/int4 weight tiers "
                         "read int4 KV, int8 reads int8)")
    ap.add_argument("--kv-page-size", type=int, default=0,
                    help="tokens per KV page in paged mode (defaults to "
                         "--page-size)")
    ap.add_argument("--attn-kernel", default="fused",
                    choices=["fused", "gather"],
                    help="paged decode attend path: 'fused' (default) "
                         "runs the Pallas paged-attention kernel straight "
                         "off the int8 page store (in-tile Matryoshka "
                         "unpack/slice/FMA + online softmax, no bf16 "
                         "cache materialization); 'gather' keeps the "
                         "materialize-then-attend fallback (the oracle "
                         "path). Ignored outside paged mode")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prompt-prefix reuse over the paged KV "
                         "store: admissions sharing a previously-served "
                         "prompt prefix attach its pages read-only "
                         "(refcounted, copy-on-write on a partial tail) "
                         "and prefill ONLY their suffix -- the summary's "
                         "'kv' section reports hit rate and hit-vs-cold "
                         "TTFT. Implies the paged cache (--kv-bits fp "
                         "when unset)")
    ap.add_argument("--elastic", action="store_true",
                    help="load-adaptive precision tiers (int8 -> int4 -> "
                         "Mix'n'Match -> int2+ep -> int2)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="serve a FLEET of this many in-process data-"
                         "parallel replicas behind one global admission "
                         "queue (serve/fleet.py): each replica is its own "
                         "engine + managed scheduler over a disjoint device "
                         "subset, and the global FleetRouter downgrades the "
                         "least-loaded replicas first under load. 0 "
                         "(default) keeps the single-scheduler path; on "
                         "CPU, force devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N so "
                         "replicas do not share one device")
    ap.add_argument("--fleet-policy", default="pin-high",
                    choices=["pin-high", "uniform"],
                    help="fleet tier policy: 'pin-high' (default) pins "
                         "replica 0 at int4-or-better so priority/deadline "
                         "requests always have a high-bit home; 'uniform' "
                         "lets every replica downgrade to int2 under "
                         "sufficient load")
    ap.add_argument("--spec-decode", action="store_true",
                    help="Matryoshka self-speculative decoding: the "
                         "--draft-tier slice of the SAME resident parent "
                         "drafts --draft-len tokens per round, the serving "
                         "tier verifies the whole block in one step. "
                         "Token-exact vs plain decode; the summary's 'spec' "
                         "section reports acceptance rate / mean accepted "
                         "prefix / verify-steps-per-token")
    ap.add_argument("--draft-tier", default="int2",
                    help="draft slice: intN or intN+ep (default int2)")
    ap.add_argument("--draft-len", type=int, default=4,
                    help="k, tokens drafted per verify step (default 4)")
    ap.add_argument("--legacy", action="store_true",
                    help="old fixed-batch run-to-completion loop")
    ap.add_argument("--ckpt", default="", help="checkpoint dir to serve from")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    engine = build_engine(args, cfg)

    spec = None
    if args.spec_decode:
        if args.legacy:
            raise SystemExit("--spec-decode rides the slot scheduler; "
                             "drop --legacy")
        draft_bits, draft_ep = parse_draft_tier(args.draft_tier)
        spec = SpecDecodeConfig(draft_bits=draft_bits,
                                draft_extra_precision=draft_ep,
                                draft_len=args.draft_len)

    if args.legacy:
        # same --seed pin as poisson_trace: one seed, one corpus
        corpus = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                            seq_len=args.prompt_len,
                                            seed=123 + args.seed))
        prompts = jnp.asarray(
            corpus.batch(0, args.requests, args.prompt_len)["tokens"])
        t0 = time.perf_counter()
        out = engine.generate_legacy(prompts, args.gen_tokens)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        tok_s = args.requests * args.gen_tokens / dt
        print(f"served {args.requests} requests x {args.gen_tokens} tokens "
              f"in {dt:.2f}s ({tok_s:.1f} tok/s)")
        print("first continuations:", out[:2].tolist())
        return out

    if args.replicas:
        if args.legacy or spec is not None:
            raise SystemExit("--replicas drives managed slot schedulers; "
                             "drop --legacy/--spec-decode")
        from repro.serve.fleet import build_fleet
        params = engine._parent_params
        if params is None:
            raise SystemExit("--replicas needs the parent checkpoint "
                             "(keep_parent)")
        fleet = build_fleet(
            params, cfg, replicas=args.replicas,
            num_slots=args.num_slots,
            max_len=args.prompt_len + args.gen_tokens,
            pinned=(0,) if args.fleet_policy == "pin-high" else (),
            clock=time.perf_counter)
        trace = build_trace(args, cfg)
        print(f"replaying {len(trace)} Poisson arrivals "
              f"(rate {args.arrival_rate}/s) through {args.replicas} "
              f"replicas ({args.fleet_policy} policy), "
              f"{args.num_slots} slots each")
        results = fleet.run_trace(trace)
        print(json.dumps(fleet.metrics.summary(), indent=2))
        first = {k: results[k].tolist() for k in sorted(results)[:2]}
        print("first continuations:", first)
        return results

    sched = engine.scheduler(elastic=args.elastic,
                             packed=args.packed if args.elastic else None,
                             spec_decode=spec)
    trace = build_trace(args, cfg)
    print(f"replaying {len(trace)} Poisson arrivals "
          f"(rate {args.arrival_rate}/s) through "
          f"{sched.num_slots} slots x {sched.capacity} tokens"
          + (" with elastic precision" if args.elastic else
             f" at fixed tier bits={engine.serve_cfg.bits}")
          + (" over packed tier planes" if args.elastic and args.packed
             else "")
          + (f", spec-decoding with a {args.draft_tier} draft slice "
             f"(k={args.draft_len})" if spec else ""))
    results = sched.run_trace(trace)
    summary = sched.metrics.summary()
    print(json.dumps(summary, indent=2))
    first = {k: results[k].tolist() for k in sorted(results)[:2]}
    print("first continuations:", first)
    return results


if __name__ == "__main__":
    main()
