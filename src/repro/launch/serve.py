"""Serving driver: batched requests against a sliced/packed model.

Demonstrates the paper's deployment story (Section 5.4): one int8
parent checkpoint, served at whatever precision the flag demands --
uniform (--bits 4), interpolated (--bits 3), or layer-wise Mix'n'Match
(--mixnmatch-bits 3.5 picks the pyramid assignment for that budget).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b --reduced \
      --bits 2 --requests 8 --prompt-len 32 --gen-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import mixnmatch
from repro.data import DataConfig, SyntheticCorpus
from repro.models import api
from repro.serve import Engine, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--mixnmatch-bits", type=float, default=None,
                    help="effective-bits budget; overrides --bits")
    ap.add_argument("--extra-precision", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--ckpt", default="", help="checkpoint dir to serve from")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = api.init(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt:
        from repro.runtime.checkpoint import CheckpointManager
        mgr = CheckpointManager(args.ckpt)
        state = mgr.restore({"params": params})
        if state is not None:
            params = state["params"]
            print(f"loaded checkpoint from {args.ckpt}")

    if args.mixnmatch_bits is not None:
        bits = mixnmatch.assign(cfg.num_layers, args.mixnmatch_bits, "pyramid")
        eff = mixnmatch.effective_bits(bits)
        print(f"mix'n'match pyramid assignment ({eff:.2f} eff bits): {bits}")
    else:
        bits = args.bits
    engine = Engine(params, cfg, ServeConfig(
        bits=bits, max_len=args.prompt_len + args.gen_tokens,
        extra_precision=args.extra_precision))

    corpus = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=args.prompt_len, seed=123))
    prompts = jnp.asarray(
        corpus.batch(0, args.requests, args.prompt_len)["tokens"])
    t0 = time.perf_counter()
    out = engine.generate(prompts, args.gen_tokens)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    tok_s = args.requests * args.gen_tokens / dt
    print(f"served {args.requests} requests x {args.gen_tokens} tokens "
          f"in {dt:.2f}s ({tok_s:.1f} tok/s)")
    print("first continuations:", out[:2].tolist())
    return out


if __name__ == "__main__":
    main()
