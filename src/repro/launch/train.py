"""End-to-end MatQuant training driver.

Elastic: builds a mesh from whatever devices exist, shards params with
the logical rules, restores from the newest checkpoint if present
(including after a topology change), and runs the fault-tolerant loop
(straggler monitor + heartbeat + checkpoint/restart).

Examples:
  # tiny CPU run of the paper's QAT MatQuant recipe
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_1_7b --reduced \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/mq

  # single-precision baseline
  ... --bitwidths 2 --parent-bits 2
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.data import DataConfig, SyntheticCorpus
from repro.launch.mesh import make_host_mesh
from repro.models import api, common as cm
from repro.optim import OptConfig
from repro.runtime import sharding as shard
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.fault import Heartbeat, StepMonitor
from repro.train import init_train_state, make_train_step


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    qcfg = QuantConfig(
        mode=args.mode,
        bitwidths=tuple(args.bitwidths),
        parent_bits=args.parent_bits,
        weights=tuple(args.lambdas) if args.lambdas else
        tuple(0.1 if b > 2 else 1.0 for b in args.bitwidths),
        scope=args.scope,
        extra_precision=args.extra_precision,
        codistill=tuple((8, s) for s in args.codistill),
    )
    cfg = cfg.replace(quant=qcfg)
    opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps,
                        warmup_steps=min(args.steps // 10, 150))
    return cfg, opt_cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", default="qat", choices=["qat", "bf16"])
    ap.add_argument("--bitwidths", type=int, nargs="+", default=[8, 4, 2])
    ap.add_argument("--parent-bits", type=int, default=8)
    ap.add_argument("--lambdas", type=float, nargs="+", default=None)
    ap.add_argument("--scope", default="ffn", choices=["ffn", "ffn+attn"])
    ap.add_argument("--extra-precision", action="store_true")
    ap.add_argument("--codistill", type=int, nargs="*", default=[],
                    help="student bit-widths distilled from int8")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--grad-compression", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg, opt_cfg = build(args)
    mesh = make_host_mesh(args.model_parallel)
    cm.set_act_resolver(shard.make_act_resolver(mesh))

    params, opt_state = init_train_state(
        jax.random.PRNGKey(args.seed), cfg, opt_cfg,
        grad_compression=args.grad_compression)
    pspec = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    psh = shard.tree_shardings(api.axes(cfg), pspec, mesh)
    params = jax.device_put(params, psh)

    step_fn = jax.jit(make_train_step(
        cfg, opt_cfg, microbatches=args.microbatches,
        grad_compression=args.grad_compression))

    corpus = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=args.seq, seed=7))
    mgr = CheckpointManager(args.ckpt_dir, keep=3, every=args.ckpt_every) \
        if args.ckpt_dir else None
    monitor = StepMonitor(on_straggler=lambda ev: print(
        f"[straggler] step {ev.step}: {ev.step_time:.2f}s vs ema {ev.ema:.2f}s"))
    hb = Heartbeat(args.ckpt_dir + "/heartbeat.json") if args.ckpt_dir else None

    start = 0
    state = {"params": params, "opt": opt_state}
    if mgr is not None:
        latest = mgr.latest()
        if latest is not None:
            state = mgr.restore(state, step=latest)
            start = latest + 1
            print(f"resumed from step {latest}")

    host_id = jax.process_index()
    n_hosts = jax.process_count()
    for step in range(start, args.steps):
        t0 = time.perf_counter()
        b = corpus.batch(step, args.batch // n_hosts, args.seq, host_id)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        p, o, metrics = step_fn(state["params"], state["opt"], batch)
        state = {"params": p, "opt": o}
        dt = time.perf_counter() - t0
        monitor.record(step, dt)
        if hb is not None:
            hb.beat(step)
        if mgr is not None:
            mgr.maybe_save(step, state)
        if step % args.log_every == 0 or step == args.steps - 1:
            ms = {k: float(v) for k, v in metrics.items()}
            per_prec = " ".join(f"int{b}={ms.get(f'ce_int{b}', float('nan')):.3f}"
                                for b in cfg.quant.bitwidths)
            print(f"step {step:5d} loss={ms['loss']:.4f} {per_prec} "
                  f"gnorm={ms['grad_norm']:.2f} {dt:.2f}s")
    if mgr is not None:
        mgr.maybe_save(args.steps - 1, state, force=True)
        mgr.wait()
    print("training complete")
    return state


if __name__ == "__main__":
    main()
