"""Pure-JAX model zoo; see repro.models.api for the unified interface."""
from repro.models import api  # noqa: F401
