"""Unified model API: family dispatch for init / forward / serve steps.

The rest of the framework (train loop, serving engine, dry-run) talks
only to this module, so adding an architecture family touches exactly
one dispatch table.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import encdec as ed
from repro.models import lm


def init(key, cfg):
    if cfg.family == "encdec":
        return ed.init_encdec(key, cfg)
    return lm.init_lm(key, cfg)


def axes(cfg):
    if cfg.family == "encdec":
        return ed.encdec_axes(cfg)
    return lm.lm_axes(cfg)


def forward(params, batch, cfg, *, bits=None):
    """batch: dict with 'tokens' (+ family extras). Returns (logits, aux)."""
    if cfg.family == "encdec":
        return ed.forward_encdec(params, batch["frames"], batch["tokens"],
                                 cfg, bits=bits)
    return lm.forward_lm(
        params, batch["tokens"], cfg, bits=bits,
        positions=batch.get("positions"),
        vision_embeds=batch.get("vision_embeds"),
    )


def init_state(cfg, batch: int, max_len: int):
    if cfg.family == "encdec":
        return ed.init_encdec_state(cfg, batch, max_len)
    return lm.init_decode_state(cfg, batch, max_len)


def state_axes(cfg):
    if cfg.family == "encdec":
        return ed.encdec_state_axes(cfg)
    return lm.decode_state_axes(cfg)


def init_paged_state(cfg, num_pages: int, page_size: int, *, kv_bits=None):
    """Paged decode state (global page store + per-slot page table
    addressing; see lm.init_paged_state). Attention-cache families only."""
    if cfg.family == "encdec":
        raise NotImplementedError("paged KV state for encdec")
    return lm.init_paged_state(cfg, num_pages, page_size, kv_bits=kv_bits)


def paged_state_axes(cfg, kv_bits=None):
    if cfg.family == "encdec":
        raise NotImplementedError("paged KV state for encdec")
    return lm.paged_state_axes(cfg, kv_bits=kv_bits)


def prefill_paged(params, batch, cfg, state, ptab, *, bits=None, last_pos,
                  start=None, kv_bits=None):
    """Prompt processing into the paged cache -- see lm.prefill_paged."""
    if cfg.family == "encdec":
        raise NotImplementedError("paged prefill for encdec")
    return lm.prefill_paged(params, batch["tokens"], state, ptab, cfg,
                            bits=bits, last_pos=last_pos, start=start,
                            kv_bits=kv_bits)


def prefill(params, batch, cfg, *, bits=None, max_len=None, last_pos=None):
    """Prompt processing -> (last-position logits, decode state).

    `last_pos` may be a scalar (one real length for the whole batch) or
    a (B,) vector (per-row lengths -- the scheduler's bucketed batched
    admission); see lm.prefill. Attention families only.
    """
    if cfg.family == "encdec":
        if last_pos is not None:
            raise NotImplementedError("last_pos gather for encdec prefill")
        return ed.prefill_encdec(params, batch["frames"], batch["tokens"],
                                 cfg, bits=bits, max_len=max_len)
    return lm.prefill(
        params, batch["tokens"], cfg, bits=bits, max_len=max_len,
        positions=batch.get("positions"),
        vision_embeds=batch.get("vision_embeds"),
        last_pos=last_pos,
    )


def decode_step(params, state, token, pos, cfg, *, bits=None):
    if cfg.family == "encdec":
        return ed.decode_step_encdec(params, state, token, pos, cfg, bits=bits)
    return lm.decode_step(params, state, token, pos, cfg, bits=bits)


def decode_step_slots(params, state, token, pos, cfg, *, bits=None,
                      ptab=None, kv_bits=None, attn_kernel: str = "fused"):
    """Slot-array decode step: pos is (B,) int32, one position per slot.

    The continuous-batching scheduler's inner step -- see
    lm.decode_step_slots (`attn_kernel` statically picks the paged
    fused-kernel vs gather read path). Attention-cache families only.
    """
    if cfg.family == "encdec":
        raise NotImplementedError("slot-wise decode for encdec")
    return lm.decode_step_slots(params, state, token, pos, cfg, bits=bits,
                                ptab=ptab, kv_bits=kv_bits,
                                attn_kernel=attn_kernel)


def verify_step_slots(params, state, tokens, pos, cfg, *, bits=None,
                      ptab=None, kv_bits=None):
    """Multi-token slot scoring: tokens is (B, T), pos (B,) the cache
    position of each slot's first token.

    The verify step of self-speculative decoding -- see
    lm.verify_step_slots. Attention-cache families only.
    """
    if cfg.family == "encdec":
        raise NotImplementedError("slot-wise verify for encdec")
    return lm.verify_step_slots(params, state, tokens, pos, cfg, bits=bits,
                                ptab=ptab, kv_bits=kv_bits)


def param_count(params) -> int:
    return int(sum(x.size for x in jax.tree.leaves(params)))
