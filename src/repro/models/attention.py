"""GQA attention: training (chunked causal), prefill, and decode paths.

Long sequences use *triangular block attention*: the query sequence is
split into static chunks and each chunk attends to the key prefix up to
its own end -- static slice bounds (Python unroll), so no wasted upper-
triangle FLOPs and no O(S^2) live score tensor. This is the jnp analogue
of a flash kernel; on TPU the same blocking maps onto VMEM tiles.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.core import quant
from repro.core.quant import QuantConfig

NEG_INF = -1e30

# Paged-KV storage precision: pages always hold 8-bit parent codes; the
# attend path slices an r-bit MSB view on the fly (Matryoshka nesting).
KV_PARENT_BITS = 8


def init_attention(key, cfg, qcfg: QuantConfig, dtype=jnp.float32):
    d, h, kh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": cm.init_linear(ks[0], d, h * hd, qcfg, kind="attn", dtype=dtype),
        "wk": cm.init_linear(ks[1], d, kh * hd, qcfg, kind="attn", dtype=dtype),
        "wv": cm.init_linear(ks[2], d, kh * hd, qcfg, kind="attn", dtype=dtype),
        "wo": cm.init_linear(ks[3], h * hd, d, qcfg, kind="attn", dtype=dtype,
                             scale=(h * hd) ** -0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def attention_axes(cfg, omn: bool = False):
    ax = {
        "wq": cm.linear_axes("embed", "q_heads", omn=omn),
        "wk": cm.linear_axes("embed", "kv_heads", omn=omn),
        "wv": cm.linear_axes("embed", "kv_heads", omn=omn),
        "wo": cm.linear_axes("q_heads", "embed", omn=omn),
    }
    if cfg.qk_norm:
        ax["q_norm"] = (None,)
        ax["k_norm"] = (None,)
    return ax


def _project_qkv(p, x, cfg, *, bits, qcfg, positions=None):
    B, S, _ = x.shape
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = cm.qlinear(p["wq"], x, bits=bits, qcfg=qcfg, kind="attn").reshape(B, S, h, hd)
    k = cm.qlinear(p["wk"], x, bits=bits, qcfg=qcfg, kind="attn").reshape(B, S, kh, hd)
    v = cm.qlinear(p["wv"], x, bits=bits, qcfg=qcfg, kind="attn").reshape(B, S, kh, hd)
    if cfg.qk_norm:
        q = cm.rmsnorm_1d(p["q_norm"], q)
        k = cm.rmsnorm_1d(p["k_norm"], k)
    if positions is not None:
        if cfg.m_rope:
            q = cm.apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
            k = cm.apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = cm.apply_rope(q, positions, cfg.rope_theta)
            k = cm.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _grouped_attend(q, k, v, mask):
    """THE grouped-einsum attend: the single oracle every softmax
    attention path in this module routes through (and the correctness
    reference for the fused paged kernel's online softmax).

    K/V are never repeated across query groups: q is viewed as
    (B, S, KH, G, D) and contracted against k (B, Sk, KH, D) directly.
    This matters under tensor parallelism -- repeating the KV tensor
    forces GSPMD to reshard (all-gather) the cache; the grouped einsum
    keeps the cache in its stored sharding and only psums the small
    partial logits when D is model-sharded. fp32 accumulation via
    preferred_element_type (inputs stay bf16 on the wire).

    mask: broadcastable to the (B, KH, G, S, Sk) logits (True = keep)
    or None. Returns fp32 (B, S, H, D).
    """
    B, S, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    scale = D**-0.5
    qg = q.reshape(B, S, KH, G, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, S, H, D)


def _sdpa(q, k, v, *, causal: bool, q_offset: int = 0):
    """Attention on one (q-block, kv-prefix) pair."""
    mask = None
    if causal:
        qi = jnp.arange(q.shape[1])[:, None] + q_offset
        ki = jnp.arange(k.shape[1])[None, :]
        mask = (ki <= qi)[None, None, None]
    return _grouped_attend(q, k, v, mask).astype(v.dtype)


def causal_attention(q, k, v, chunk: int = 1024):
    """Triangular block attention. q: (B,S,H,D); k,v: (B,S,KH,D)."""
    B, S, H, D = q.shape
    if S <= chunk:
        return _sdpa(q, k, v, causal=True)
    n = math.ceil(S / chunk)
    outs = []
    for i in range(n):
        lo, hi = i * chunk, min((i + 1) * chunk, S)
        outs.append(
            _sdpa(q[:, lo:hi], k[:, :hi], v[:, :hi], causal=True, q_offset=lo)
        )
    return jnp.concatenate(outs, axis=1)


def full_attention(q, k, v):
    """Bidirectional attention (encoder / cross)."""
    return _sdpa(q, k, v, causal=False)


def apply_attention(
    p, x, cfg, *, bits, qcfg: QuantConfig, positions, causal: bool = True,
    chunk: int = 1024,
):
    """Training/prefill forward. x: (B, S, d) -> (B, S, d)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, bits=bits, qcfg=qcfg, positions=positions)
    if causal:
        o = causal_attention(q, k, v, chunk=chunk)
    else:
        o = full_attention(q, k, v)
    o = o.reshape(B, S, cfg.num_heads * cfg.resolved_head_dim)
    return cm.qlinear(p["wo"], o, bits=bits, qcfg=qcfg, kind="attn")


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16, layers: int | None = None):
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (batch, max_len, kh, hd)
    if layers is not None:
        shape = (layers,) + shape
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_axes(layers: bool = True):
    base = ("batch", "kv_seq", "kv_heads_cache", "head_dim_cache")
    if layers:
        base = ("layer",) + base
    return {"k": base, "v": base}


def _write_seq_slots(cache, k_new, v_new, pos):
    """Scatter per-slot K/V rows into a dense slot cache.

    cache: {"k","v"} (B, max_len, kh, hd); k_new/v_new: (B, T, kh, hd);
    pos: (B,) int32 first write index per slot. Row b gets its T new
    rows at pos[b]..pos[b]+T-1 in one block update (T=1 is the decode
    step, T>1 the spec-decode verify block).
    """

    def upd(c, n, p_):  # c: (max_len, kh, hd); n: (T, kh, hd)
        return jax.lax.dynamic_update_slice_in_dim(c, n, p_, axis=0)

    return {"k": jax.vmap(upd)(cache["k"], k_new.astype(cache["k"].dtype), pos),
            "v": jax.vmap(upd)(cache["v"], v_new.astype(cache["v"].dtype), pos)}


def _attend_slots(q, k_cache, v_cache, qpos, h, kh, hd):
    """Grouped-einsum attend of per-slot queries against a full cache.

    q: (B, T, h, hd); k_cache/v_cache: (B, Sk, kh, hd); qpos: (B, T)
    per-query positions -- key row ki is visible to query j iff
    ki <= qpos[b, j]. Returns fp32 (B, T, h*hd).
    """
    B, T = q.shape[:2]
    mask = jnp.arange(k_cache.shape[1])[None, None, :] <= qpos[:, :, None]
    o = _grouped_attend(q, k_cache.astype(q.dtype), v_cache,
                        mask[:, None, None, :, :])
    return o.reshape(B, T, h * hd)


def decode_attention_slots(
    p, x, cache, pos, cfg, *, bits, qcfg: QuantConfig,
):
    """One-token decode with PER-SLOT positions (continuous batching).

    x: (B, 1, d); pos: (B,) int32, each slot's current write index. Every
    slot writes its new k/v at its own cache row/position and attends to
    its own prefix only -- the batch axis is a slot array where rows may
    belong to different requests at different depths.
    """
    B = x.shape[0]
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    pos = pos.astype(jnp.int32)
    positions = pos[:, None]
    if cfg.m_rope:
        positions = jnp.broadcast_to(pos[:, None, None], (B, 1, 3))
    q, k_new, v_new = _project_qkv(p, x, cfg, bits=bits, qcfg=qcfg, positions=positions)
    cache = _write_seq_slots(cache, k_new, v_new, pos)
    o = _attend_slots(q, cache["k"], cache["v"], pos[:, None], h, kh, hd)
    out = cm.qlinear(p["wo"], o.astype(x.dtype), bits=bits, qcfg=qcfg, kind="attn")
    return out, cache


def verify_attention_slots(
    p, x, cache, pos, cfg, *, bits, qcfg: QuantConfig,
):
    """Multi-token scoring with PER-SLOT start positions (spec decode).

    x: (B, T, d); pos: (B,) int32, each slot's first write index. Slot b
    writes its T new k/v rows at pos[b]..pos[b]+T-1 in one block update
    and query j attends causally to its own prefix (ki <= pos[b] + j).
    The verify step of self-speculative decoding: all k+1 draft
    positions scored in ONE batched step. Write-then-attend over the
    full cache with the same grouped einsums as
    `decode_attention_slots`, so a T=1 call is that function exactly --
    and stale draft rows beyond the accepted prefix are masked, never
    read.
    """
    B, T = x.shape[:2]
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    pos = pos.astype(jnp.int32)
    positions = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    if cfg.m_rope:
        positions = jnp.broadcast_to(positions[:, :, None], (B, T, 3))
    q, k_new, v_new = _project_qkv(p, x, cfg, bits=bits, qcfg=qcfg, positions=positions)
    cache = _write_seq_slots(cache, k_new, v_new, pos)
    qpos = positions[..., 0] if cfg.m_rope else positions
    o = _attend_slots(q, cache["k"], cache["v"], qpos, h, kh, hd)
    out = cm.qlinear(p["wo"], o.astype(x.dtype), bits=bits, qcfg=qcfg, kind="attn")
    return out, cache


def decode_attention(
    p, x, cache, pos, cfg, *, bits, qcfg: QuantConfig,
):
    """One-token decode. x: (B, 1, d); pos: scalar int32 current index.

    Returns (out (B, 1, d), updated cache). The cache holds max_len
    entries; positions > pos are masked out.
    """
    B = x.shape[0]
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    if cfg.m_rope:
        positions = jnp.broadcast_to(pos, (B, 1, 3)).astype(jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg, bits=bits, qcfg=qcfg, positions=positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1
    )
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1
    )
    # grouped einsum: the cache is consumed in its stored sharding; no
    # head-repeat, no resharding, fp32 accumulation only.
    mask = (jnp.arange(k_cache.shape[1]) <= pos)[None, None, None, None, :]
    o = _grouped_attend(q, k_cache.astype(q.dtype), v_cache, mask)
    o = o.reshape(B, 1, h * hd)
    out = cm.qlinear(p["wo"], o.astype(x.dtype), bits=bits, qcfg=qcfg, kind="attn")
    return out, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# Paged KV cache (Matryoshka int8 pages, sliced low-bit attend views)
# ---------------------------------------------------------------------------
#
# The paged layout replaces the dense per-slot (B, max_len, kh, hd)
# cache with a GLOBAL page store (num_pages, page_size, kh, hd) plus a
# per-slot page table (B, pages_per_slot) of physical page ids. Page id
# == num_pages is the "hole" sentinel: scatters drop it (mode="drop"),
# gathers fill zeros (mode="fill"), so unreserved table entries are
# harmless at both ends.
#
# Quantized mode stores 8-bit MinMax codes per (token row, kv head)
# with fp32 scale alpha and offset beta = alpha * z alongside each
# page. An r-bit attend view (r in {8, 4, 2}) is an MSB slice of the
# SAME codes -- `core.quant.slice_bits` on the parent grid -- so the
# row dequantizes as  x_hat = alpha * S(q8, r) - beta  with no second
# copy of the cache and an r-independent offset (the Matryoshka
# property, applied to activations).


def init_paged_cache(cfg, num_pages: int, page_size: int, *,
                     layers: int | None = None, kv_bits=None,
                     dtype=jnp.bfloat16):
    """Global page store. fp mode (kv_bits=None): {"kp","vp"} pages in
    `dtype`. Quantized mode: uint8 code pages plus per-(row, head) fp32
    scale/offset planes {"ks","kb","vs","vb"}."""
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    lead = () if layers is None else (layers,)
    shape = lead + (num_pages, page_size, kh, hd)
    if kv_bits is None:
        return {"kp": jnp.zeros(shape, dtype), "vp": jnp.zeros(shape, dtype)}
    sshape = lead + (num_pages, page_size, kh)
    return {"kp": jnp.zeros(shape, jnp.uint8),
            "vp": jnp.zeros(shape, jnp.uint8),
            "ks": jnp.zeros(sshape, jnp.float32),
            "kb": jnp.zeros(sshape, jnp.float32),
            "vs": jnp.zeros(sshape, jnp.float32),
            "vb": jnp.zeros(sshape, jnp.float32)}


def paged_cache_axes(quantized: bool, layers: bool = True):
    base = ("page", "page_row", "kv_heads_cache", "head_dim_cache")
    sc = ("page", "page_row", "kv_heads_cache")
    if layers:
        base = ("layer",) + base
        sc = ("layer",) + sc
    ax = {"kp": base, "vp": base}
    if quantized:
        ax.update({"ks": sc, "kb": sc, "vs": sc, "vb": sc})
    return ax


def quant_kv_rows(x):
    """Asymmetric 8-bit MinMax codes per (token row, kv head) over hd.

    Returns (codes uint8, alpha, beta) with alpha/beta shaped like x
    minus the trailing head_dim axis; beta = alpha * z so the r-bit
    dequant offset is independent of r."""
    q, alpha, z = quant.quantize(x.astype(jnp.float32), KV_PARENT_BITS,
                                 axis=-1)
    return q.astype(jnp.uint8), alpha[..., 0], (alpha * z)[..., 0]


def dequant_kv_rows(codes, alpha, beta, bits: int, dtype):
    """Dequantize the r-bit MSB view of stored 8-bit codes.

    `quant.slice_bits` re-scales the sliced codes to the parent grid,
    so one fused multiply-add recovers the row at any r. The FMA runs
    directly in the attend dtype (codes are integers <= 255, exact in
    bf16): no fp32 intermediate of the full cache view is materialized
    before the cast, and at dtype=float32 the result is bit-identical
    to the old fp32-then-cast path."""
    grid = quant.slice_bits(codes.astype(jnp.int32), KV_PARENT_BITS, bits)
    return (alpha[..., None].astype(dtype) * grid.astype(dtype)
            - beta[..., None].astype(dtype))


def _page_coords(ptab, positions, page_size: int):
    """(page id, row-in-page) of token `positions` under page-table rows.

    ptab: (B, pages_per_slot) int32 physical page ids (num_pages ==
    hole); positions: (B, T) int32 token indices. Unreserved positions
    resolve to the hole sentinel."""
    pids = jnp.take_along_axis(ptab, positions // page_size, axis=1)
    rows = positions % page_size
    return pids, rows


def write_pages(cache_l, k_new, v_new, pids, rows):
    """Scatter (B, T) new K/V rows into one layer's page store.

    cache_l leaves: kp/vp (P, page_size, kh, hd) (+ scale planes in
    quantized mode); k_new/v_new: (B, T, kh, hd); pids/rows: (B, T).
    Hole page ids (== P) are dropped. Quantized mode quantizes each new
    row on the spot -- rows are written exactly once, so no existing
    code is ever re-quantized."""
    if "ks" not in cache_l:
        return {
            "kp": cache_l["kp"].at[pids, rows].set(
                k_new.astype(cache_l["kp"].dtype), mode="drop"),
            "vp": cache_l["vp"].at[pids, rows].set(
                v_new.astype(cache_l["vp"].dtype), mode="drop"),
        }
    kq, ka, kb = quant_kv_rows(k_new)
    vq, va, vb = quant_kv_rows(v_new)
    return {
        "kp": cache_l["kp"].at[pids, rows].set(kq, mode="drop"),
        "vp": cache_l["vp"].at[pids, rows].set(vq, mode="drop"),
        "ks": cache_l["ks"].at[pids, rows].set(ka, mode="drop"),
        "kb": cache_l["kb"].at[pids, rows].set(kb, mode="drop"),
        "vs": cache_l["vs"].at[pids, rows].set(va, mode="drop"),
        "vb": cache_l["vb"].at[pids, rows].set(vb, mode="drop"),
    }


def gather_slot_view(cache_l, ptab, *, kv_bits=None, dtype=jnp.bfloat16):
    """Per-slot (B, pages_per_slot * page_size, kh, hd) K/V read view.

    Gathers each slot's pages from the global store (hole entries fill
    zeros) and, in quantized mode, dequantizes the r-bit MSB view at
    `kv_bits` in the same fused expression the attend consumes."""

    def gather(a):
        g = jnp.take(a, ptab, axis=0, mode="fill", fill_value=0)
        return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])

    if "ks" not in cache_l:
        return gather(cache_l["kp"]), gather(cache_l["vp"])
    bits = KV_PARENT_BITS if kv_bits is None else kv_bits
    k = dequant_kv_rows(gather(cache_l["kp"]), gather(cache_l["ks"]),
                        gather(cache_l["kb"]), bits, dtype)
    v = dequant_kv_rows(gather(cache_l["vp"]), gather(cache_l["vs"]),
                        gather(cache_l["vb"]), bits, dtype)
    return k, v


def paged_decode_attention_slots(
    p, x, cache_l, ptab, pos, cfg, *, bits, qcfg: QuantConfig, kv_bits=None,
    attn_kernel: str = "fused",
):
    """`decode_attention_slots` over one layer's paged cache.

    x: (B, 1, d); ptab: (B, pages_per_slot) page table rows of the
    slots being stepped; pos: (B,) per-slot write index. Writes the new
    row through the page table, then attends. `attn_kernel` (static)
    picks the read path:

    * "fused"  -- the Pallas kernel (`kernels.ops.paged_attend`)
      attends straight off the int8 page store: per-page tiles unpack,
      MSB-slice at `kv_bits`, FMA-dequantize in-register and fold into
      an online softmax; the dequantized (B, cache_len, kh, hd) view is
      never materialized.
    * "gather" -- the original gather+dequant fallback
      (`gather_slot_view` + `_grouped_attend`); with pages_per_slot *
      page_size == cache_len the reduction shape (and, in fp mode,
      every elementwise value) matches the dense slot path exactly.
    """
    B = x.shape[0]
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    pos = pos.astype(jnp.int32)
    positions = pos[:, None]
    if cfg.m_rope:
        positions = jnp.broadcast_to(pos[:, None, None], (B, 1, 3))
    q, k_new, v_new = _project_qkv(p, x, cfg, bits=bits, qcfg=qcfg,
                                   positions=positions)
    page_size = cache_l["kp"].shape[1]
    pids, rows = _page_coords(ptab, pos[:, None], page_size)
    cache_l = write_pages(cache_l, k_new, v_new, pids, rows)
    if attn_kernel == "fused":
        from repro.kernels import ops as _ops
        qg = q[:, 0].reshape(B, kh, h // kh, hd)
        o = _ops.paged_attend(qg, cache_l, ptab, pos,
                              kv_bits=kv_bits).reshape(B, 1, h * hd)
    else:
        k_view, v_view = gather_slot_view(cache_l, ptab, kv_bits=kv_bits,
                                          dtype=x.dtype)
        o = _attend_slots(q, k_view, v_view, pos[:, None], h, kh, hd)
    out = cm.qlinear(p["wo"], o.astype(x.dtype), bits=bits, qcfg=qcfg,
                     kind="attn")
    return out, cache_l


def paged_verify_attention_slots(
    p, x, cache_l, ptab, pos, cfg, *, bits, qcfg: QuantConfig, kv_bits=None,
):
    """`verify_attention_slots` over one layer's paged cache.

    x: (B, T, d); slot b writes rows pos[b]..pos[b]+T-1 through its
    page table and query j attends to ki <= pos[b] + j. Doubles as the
    prefix-hit prefill body (T = suffix block, pos = shared prefix
    length). Stale draft rows past an accepted prefix need no rollback
    scrub: the ki <= pos mask hides them until the next write lands on
    the same (page, row)."""
    B, T = x.shape[:2]
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    pos = pos.astype(jnp.int32)
    positions = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    qpos = positions
    if cfg.m_rope:
        positions = jnp.broadcast_to(positions[:, :, None], (B, T, 3))
    q, k_new, v_new = _project_qkv(p, x, cfg, bits=bits, qcfg=qcfg,
                                   positions=positions)
    page_size = cache_l["kp"].shape[1]
    pids, rows = _page_coords(ptab, qpos, page_size)
    cache_l = write_pages(cache_l, k_new, v_new, pids, rows)
    k_view, v_view = gather_slot_view(cache_l, ptab, kv_bits=kv_bits,
                                      dtype=x.dtype)
    o = _attend_slots(q, k_view, v_view, qpos, h, kh, hd)
    out = cm.qlinear(p["wo"], o.astype(x.dtype), bits=bits, qcfg=qcfg,
                     kind="attn")
    return out, cache_l
