"""GQA attention: training (chunked causal), prefill, and decode paths.

Long sequences use *triangular block attention*: the query sequence is
split into static chunks and each chunk attends to the key prefix up to
its own end -- static slice bounds (Python unroll), so no wasted upper-
triangle FLOPs and no O(S^2) live score tensor. This is the jnp analogue
of a flash kernel; on TPU the same blocking maps onto VMEM tiles.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.core.quant import QuantConfig

NEG_INF = -1e30


def init_attention(key, cfg, qcfg: QuantConfig, dtype=jnp.float32):
    d, h, kh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": cm.init_linear(ks[0], d, h * hd, qcfg, kind="attn", dtype=dtype),
        "wk": cm.init_linear(ks[1], d, kh * hd, qcfg, kind="attn", dtype=dtype),
        "wv": cm.init_linear(ks[2], d, kh * hd, qcfg, kind="attn", dtype=dtype),
        "wo": cm.init_linear(ks[3], h * hd, d, qcfg, kind="attn", dtype=dtype,
                             scale=(h * hd) ** -0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def attention_axes(cfg, omn: bool = False):
    ax = {
        "wq": cm.linear_axes("embed", "q_heads", omn=omn),
        "wk": cm.linear_axes("embed", "kv_heads", omn=omn),
        "wv": cm.linear_axes("embed", "kv_heads", omn=omn),
        "wo": cm.linear_axes("q_heads", "embed", omn=omn),
    }
    if cfg.qk_norm:
        ax["q_norm"] = (None,)
        ax["k_norm"] = (None,)
    return ax


def _project_qkv(p, x, cfg, *, bits, qcfg, positions=None):
    B, S, _ = x.shape
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = cm.qlinear(p["wq"], x, bits=bits, qcfg=qcfg, kind="attn").reshape(B, S, h, hd)
    k = cm.qlinear(p["wk"], x, bits=bits, qcfg=qcfg, kind="attn").reshape(B, S, kh, hd)
    v = cm.qlinear(p["wv"], x, bits=bits, qcfg=qcfg, kind="attn").reshape(B, S, kh, hd)
    if cfg.qk_norm:
        q = cm.rmsnorm_1d(p["q_norm"], q)
        k = cm.rmsnorm_1d(p["k_norm"], k)
    if positions is not None:
        if cfg.m_rope:
            q = cm.apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
            k = cm.apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = cm.apply_rope(q, positions, cfg.rope_theta)
            k = cm.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, *, causal: bool, q_offset: int = 0):
    """Attention on one (q-block, kv-prefix) pair, GROUPED einsum form.

    K/V are never repeated across query groups: q is viewed as
    (B, Sq, KH, G, D) and contracted against k (B, Sk, KH, D) directly.
    This matters under tensor parallelism -- repeating the KV tensor
    forces GSPMD to reshard (all-gather) the cache; the grouped einsum
    keeps the cache in its stored sharding and only psums the small
    partial logits when D is model-sharded. fp32 accumulation via
    preferred_element_type (inputs stay bf16 on the wire).
    """
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    scale = D**-0.5
    qg = q.reshape(B, Sq, KH, G, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        qi = jnp.arange(Sq)[:, None] + q_offset
        ki = jnp.arange(k.shape[1])[None, :]
        logits = jnp.where(ki[None, None, None] <= qi[None, None, None],
                           logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H, D).astype(v.dtype)


def causal_attention(q, k, v, chunk: int = 1024):
    """Triangular block attention. q: (B,S,H,D); k,v: (B,S,KH,D)."""
    B, S, H, D = q.shape
    if S <= chunk:
        return _sdpa(q, k, v, causal=True)
    n = math.ceil(S / chunk)
    outs = []
    for i in range(n):
        lo, hi = i * chunk, min((i + 1) * chunk, S)
        outs.append(
            _sdpa(q[:, lo:hi], k[:, :hi], v[:, :hi], causal=True, q_offset=lo)
        )
    return jnp.concatenate(outs, axis=1)


def full_attention(q, k, v):
    """Bidirectional attention (encoder / cross)."""
    return _sdpa(q, k, v, causal=False)


def apply_attention(
    p, x, cfg, *, bits, qcfg: QuantConfig, positions, causal: bool = True,
    chunk: int = 1024,
):
    """Training/prefill forward. x: (B, S, d) -> (B, S, d)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, bits=bits, qcfg=qcfg, positions=positions)
    if causal:
        o = causal_attention(q, k, v, chunk=chunk)
    else:
        o = full_attention(q, k, v)
    o = o.reshape(B, S, cfg.num_heads * cfg.resolved_head_dim)
    return cm.qlinear(p["wo"], o, bits=bits, qcfg=qcfg, kind="attn")


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16, layers: int | None = None):
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (batch, max_len, kh, hd)
    if layers is not None:
        shape = (layers,) + shape
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_axes(layers: bool = True):
    base = ("batch", "kv_seq", "kv_heads_cache", "head_dim_cache")
    if layers:
        base = ("layer",) + base
    return {"k": base, "v": base}


def decode_attention_slots(
    p, x, cache, pos, cfg, *, bits, qcfg: QuantConfig,
):
    """One-token decode with PER-SLOT positions (continuous batching).

    x: (B, 1, d); pos: (B,) int32, each slot's current write index. Every
    slot writes its new k/v at its own cache row/position and attends to
    its own prefix only -- the batch axis is a slot array where rows may
    belong to different requests at different depths.
    """
    B = x.shape[0]
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    pos = pos.astype(jnp.int32)
    positions = pos[:, None]
    if cfg.m_rope:
        positions = jnp.broadcast_to(pos[:, None, None], (B, 1, 3))
    q, k_new, v_new = _project_qkv(p, x, cfg, bits=bits, qcfg=qcfg, positions=positions)

    def upd(c, n, p_):  # c: (max_len, kh, hd); n: (1, kh, hd)
        return jax.lax.dynamic_update_slice_in_dim(c, n, p_, axis=0)

    k_cache = jax.vmap(upd)(cache["k"], k_new.astype(cache["k"].dtype), pos)
    v_cache = jax.vmap(upd)(cache["v"], v_new.astype(cache["v"].dtype), pos)
    G = h // kh
    qg = q.reshape(B, 1, kh, G, hd)
    scale = hd**-0.5
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache.astype(q.dtype),
                        preferred_element_type=jnp.float32) * scale
    mask = (jnp.arange(k_cache.shape[1])[None, :] <= pos[:, None])
    logits = jnp.where(mask[:, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v_cache,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, h * hd)
    out = cm.qlinear(p["wo"], o.astype(x.dtype), bits=bits, qcfg=qcfg, kind="attn")
    return out, {"k": k_cache, "v": v_cache}


def verify_attention_slots(
    p, x, cache, pos, cfg, *, bits, qcfg: QuantConfig,
):
    """Multi-token scoring with PER-SLOT start positions (spec decode).

    x: (B, T, d); pos: (B,) int32, each slot's first write index. Slot b
    writes its T new k/v rows at pos[b]..pos[b]+T-1 in one block update
    and query j attends causally to its own prefix (ki <= pos[b] + j).
    The verify step of self-speculative decoding: all k+1 draft
    positions scored in ONE batched step. Write-then-attend over the
    full cache with the same grouped einsums as
    `decode_attention_slots`, so a T=1 call is that function exactly --
    and stale draft rows beyond the accepted prefix are masked, never
    read.
    """
    B, T = x.shape[:2]
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    pos = pos.astype(jnp.int32)
    positions = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    if cfg.m_rope:
        positions = jnp.broadcast_to(positions[:, :, None], (B, T, 3))
    q, k_new, v_new = _project_qkv(p, x, cfg, bits=bits, qcfg=qcfg, positions=positions)

    def upd(c, n, p_):  # c: (max_len, kh, hd); n: (T, kh, hd)
        return jax.lax.dynamic_update_slice_in_dim(c, n, p_, axis=0)

    k_cache = jax.vmap(upd)(cache["k"], k_new.astype(cache["k"].dtype), pos)
    v_cache = jax.vmap(upd)(cache["v"], v_new.astype(cache["v"].dtype), pos)
    G = h // kh
    qg = q.reshape(B, T, kh, G, hd)
    scale = hd**-0.5
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache.astype(q.dtype),
                        preferred_element_type=jnp.float32) * scale
    qpos = positions[..., 0] if cfg.m_rope else positions
    mask = jnp.arange(k_cache.shape[1])[None, None, :] <= qpos[:, :, None]
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v_cache,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, T, h * hd)
    out = cm.qlinear(p["wo"], o.astype(x.dtype), bits=bits, qcfg=qcfg, kind="attn")
    return out, {"k": k_cache, "v": v_cache}


def decode_attention(
    p, x, cache, pos, cfg, *, bits, qcfg: QuantConfig,
):
    """One-token decode. x: (B, 1, d); pos: scalar int32 current index.

    Returns (out (B, 1, d), updated cache). The cache holds max_len
    entries; positions > pos are masked out.
    """
    B = x.shape[0]
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    if cfg.m_rope:
        positions = jnp.broadcast_to(pos, (B, 1, 3)).astype(jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg, bits=bits, qcfg=qcfg, positions=positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1
    )
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1
    )
    # grouped einsum: the cache is consumed in its stored sharding; no
    # head-repeat, no resharding, fp32 accumulation only.
    G = h // kh
    qg = q.reshape(B, 1, kh, G, hd)
    scale = hd**-0.5
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache.astype(q.dtype),
                        preferred_element_type=jnp.float32) * scale
    mask = (jnp.arange(k_cache.shape[1]) <= pos)[None, None, None, None, :]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v_cache,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, h * hd)
    out = cm.qlinear(p["wo"], o.astype(x.dtype), bits=bits, qcfg=qcfg, kind="attn")
    return out, {"k": k_cache, "v": v_cache}
