"""Shared model building blocks (pure JAX, pytree params).

Conventions
-----------
* A "module" is an `init_*(key, cfg) -> params` function plus an
  `apply`-style pure function. Params are plain nested dicts.
* Every parameter has a parallel *logical axes* entry (same tree
  structure, leaves = tuple of logical axis names) produced by the
  matching `*_axes` function; `repro.runtime.sharding` maps logical
  axes onto the device mesh.
* Quantized projections route through `qlinear`, the single integration
  point of MatQuant with every architecture. `bits` may be None (bf16),
  a Python int, or a traced scalar (dynamic per-layer Mix'n'Match).
"""

from __future__ import annotations

import contextvars
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import omniquant as omni
from repro.core.quant import QuantConfig, fake_quant

# ---------------------------------------------------------------------------
# Activation-sharding hook. The launcher installs a resolver mapping
# logical activation axes -> PartitionSpec; inside plain tests it is a
# no-op so models stay mesh-agnostic.
# ---------------------------------------------------------------------------

_ACT_RESOLVER: contextvars.ContextVar[Callable | None] = contextvars.ContextVar(
    "act_resolver", default=None
)


def set_act_resolver(fn: Callable | None):
    return _ACT_RESOLVER.set(fn)


def constrain(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """with_sharding_constraint via installed logical-axis resolver."""
    resolver = _ACT_RESOLVER.get()
    if resolver is None:
        return x
    spec = resolver((logical_axes, x.shape))
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def scan_layers(body, carry, xs, unroll: bool = False):
    """lax.scan over stacked layer params, or a Python unroll.

    The unrolled form exists for *cost analysis*: XLA's cost_analysis
    counts a while-loop body once regardless of trip count, so the
    roofline harness compiles shallow unrolled variants and
    extrapolates per-layer terms (launch/roofline.py).

    A top-level component of `xs` may also be a Python LIST of
    per-layer subtrees instead of a stacked pytree -- the layout of
    packed Mix'n'Match serving params, where each layer's packed planes
    have bitwidth-dependent shapes and cannot stack. Lists force the
    unrolled path (heterogeneous shapes cannot scan); list components
    are indexed per layer, stacked components sliced as usual.
    """
    comps = xs if isinstance(xs, tuple) else (xs,)
    has_list = any(isinstance(c, list) for c in comps)
    if not unroll and not has_list:
        return jax.lax.scan(body, carry, xs)
    if has_list:
        L = len(next(c for c in comps if isinstance(c, list)))
    else:
        L = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        parts = tuple(c[i] if isinstance(c, list)
                      else jax.tree.map(lambda a: a[i], c) for c in comps)
        x_i = parts if isinstance(xs, tuple) else parts[0]
        carry, y = body(carry, x_i)
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return carry, stacked


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None):
    scale = (d_in**-0.5) if scale is None else scale
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Quantized linear -- the paper's integration point.
# ---------------------------------------------------------------------------


def init_linear(key, d_in, d_out, qcfg: QuantConfig, kind: str = "ffn",
                dtype=jnp.float32, bias: bool = False, scale=None):
    p = {"w": dense_init(key, d_in, d_out, dtype, scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    if qcfg.mode == "omniquant" and _in_scope(qcfg, kind):
        p["omni"] = omni.init_aux(d_in, d_out, jnp.float32)
    return p


def linear_axes(a_in: str, a_out: str, bias: bool = False, omn: bool = False):
    ax = {"w": (a_in, a_out)}
    if bias:
        ax["b"] = (a_out,)
    if omn:
        ax["omni"] = {
            "gamma_logit": (None, a_out),
            "beta_logit": (None, a_out),
            "shift": (a_in,),
            "log_scale": (a_in,),
        }
    return ax


def _in_scope(qcfg: QuantConfig, kind: str) -> bool:
    if kind == "ffn":
        return True
    return kind in qcfg.scope  # 'attn' in 'ffn+attn'


def qlinear(p, x, *, bits, qcfg: QuantConfig, kind: str = "ffn"):
    """x @ W with MatQuant fake-quantization applied per mode/scope.

    x: (..., d_in); returns (..., d_out) in x.dtype. If `p` holds a
    PACKED plane (a `core.packing.PackedPlane` from
    serve.engine.materialize_packed_params), it routes through
    kernels.ops.plane_matmul with the plane's bitwidth static (per-layer
    Mix'n'Match planes each carry their own): the Pallas dequant-matmul
    kernel when qcfg.packed_kernel (TPU / interpret tests), else its jnp
    unpack twin -- identical math either way.
    """
    from repro.core.packing import PackedPlane
    pw = p.get("w")
    if isinstance(pw, PackedPlane):
        from repro.kernels import ops as _ops
        y = _ops.plane_matmul(x, pw, use_kernel=qcfg.packed_kernel)
        return y if p.get("b") is None else y + p["b"].astype(y.dtype)
    w = pw
    b = p.get("b")
    if bits is None or qcfg.mode == "bf16" or not _in_scope(qcfg, kind):
        y = x @ w.astype(x.dtype)
        return y if b is None else y + b.astype(y.dtype)
    if qcfg.mode == "qat":
        w_q = fake_quant(
            w, qcfg.parent_bits, bits, axis=0,
            extra_precision=qcfg.extra_precision,
        )
        y = x @ w_q.astype(x.dtype)
        return y if b is None else y + b.astype(y.dtype)
    if qcfg.mode == "omniquant":
        y = omni.apply_linear(
            jax.lax.stop_gradient(w), p["omni"], x, bits,
            parent_bits=qcfg.parent_bits,
            extra_precision=qcfg.extra_precision,
            bias=b,
        )
        return y
    raise ValueError(f"unknown quant mode {qcfg.mode!r}")


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def rmsnorm_1d(scale, x, eps: float = 1e-6):
    """RMSNorm with a raw scale vector (used for per-head qk-norm)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings: standard RoPE and Qwen2-VL's multimodal M-RoPE.
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0):
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, D/2)
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    sections: tuple[int, int, int],
    theta: float = 10000.0,
):
    """Qwen2-VL M-RoPE. positions: (B, S, 3) = (t, h, w) ids.

    Frequency channels are partitioned into three contiguous sections
    (temporal, height, width); each section rotates by its own position
    stream. Text tokens carry t == h == w so M-RoPE degenerates to RoPE.
    """
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(d, theta)                       # (half,)
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half
    )                                                # (half,) in {0,1,2}
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),
        jnp.broadcast_to(sec_id[None, None, :], positions.shape[:2] + (half,)).astype(jnp.int32),
        axis=-1,
    )                                                # (B, S, half)
    ang = pos * inv
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def remat(fn, policy: str = "block"):
    """jax.checkpoint with a named policy.

    'block' -- recompute everything (minimum memory, +1 forward of FLOPs)
    'dots'  -- save matmul outputs without batch dims (recompute only the
               cheap elementwise chain; trades stash bytes for ~25% fewer
               backward FLOPs vs 'block')
    """
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)
