"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment, the conv frontend is a STUB: `input_specs()` feeds
precomputed frame embeddings (B, encoder_len, d_model) directly into the
encoder. Blocks use LayerNorm + non-gated GELU MLP (Whisper style);
positions are learned-free sinusoid-equivalent RoPE for simplicity of a
backbone reproduction (noted in DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common as cm
from repro.models import ffn as ffn_mod


def _dtype(cfg):
    return jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32


def init_encdec(key, cfg):
    dtype = _dtype(cfg)
    qcfg = cfg.quant
    k_emb, k_enc, k_dec = jax.random.split(key, 3)
    V = cfg.padded_vocab

    def enc_block(k):
        ka, kf = jax.random.split(k)
        return {
            "norm1": cm.init_layernorm(cfg.d_model, dtype),
            "attn": attn.init_attention(ka, cfg, qcfg, dtype),
            "norm2": cm.init_layernorm(cfg.d_model, dtype),
            "ffn": ffn_mod.init_ffn(kf, cfg.d_model, cfg.d_ff, qcfg, dtype,
                                    gated=False, bias=True),
        }

    def dec_block(k):
        ka, kx, kf = jax.random.split(k, 3)
        return {
            "norm1": cm.init_layernorm(cfg.d_model, dtype),
            "self_attn": attn.init_attention(ka, cfg, qcfg, dtype),
            "norm_x": cm.init_layernorm(cfg.d_model, dtype),
            "cross_attn": attn.init_attention(kx, cfg, qcfg, dtype),
            "norm2": cm.init_layernorm(cfg.d_model, dtype),
            "ffn": ffn_mod.init_ffn(kf, cfg.d_model, cfg.d_ff, qcfg, dtype,
                                    gated=False, bias=True),
        }

    return {
        "embed": {"w": cm.embed_init(k_emb, V, cfg.d_model, dtype)},
        "encoder": jax.vmap(enc_block)(jax.random.split(k_enc, cfg.encoder_layers)),
        "decoder": jax.vmap(dec_block)(jax.random.split(k_dec, cfg.num_layers)),
        "enc_norm": cm.init_layernorm(cfg.d_model, dtype),
        "final_norm": cm.init_layernorm(cfg.d_model, dtype),
    }


def encdec_axes(cfg):
    omn = cfg.quant.mode == "omniquant"
    ln = {"scale": ("embed",), "bias": ("embed",)}

    def stack(b):
        return jax.tree.map(lambda t: ("layer",) + t, b,
                            is_leaf=lambda x: isinstance(x, tuple))

    enc = {"norm1": ln, "attn": attn.attention_axes(cfg, omn),
           "norm2": ln, "ffn": ffn_mod.ffn_axes(False, omn, bias=True)}
    dec = {"norm1": ln, "self_attn": attn.attention_axes(cfg, omn),
           "norm_x": ln, "cross_attn": attn.attention_axes(cfg, omn),
           "norm2": ln, "ffn": ffn_mod.ffn_axes(False, omn, bias=True)}
    return {
        "embed": {"w": ("vocab", None)},
        "encoder": stack(enc),
        "decoder": stack(dec),
        "enc_norm": ln,
        "final_norm": ln,
    }


def _cross_attention(p, x, enc_kv, cfg, *, bits, qcfg):
    """x: (B, S, d) queries; enc_kv: precomputed (k, v) (B, T_enc, KH, hd)."""
    B, S, _ = x.shape
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    q = cm.qlinear(p["wq"], x, bits=bits, qcfg=qcfg, kind="attn").reshape(B, S, h, hd)
    o = attn.full_attention(q, enc_kv["k"].astype(q.dtype), enc_kv["v"].astype(q.dtype))
    o = o.reshape(B, S, h * hd)
    return cm.qlinear(p["wo"], o, bits=bits, qcfg=qcfg, kind="attn")


def encode(params, frames, cfg, *, bits=None):
    """frames: (B, T_enc, d) stub embeddings -> (B, T_enc, d)."""
    qcfg = cfg.quant
    B, T, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    h = frames

    def body(x, lp):
        x = x + attn.apply_attention(
            lp["attn"], cm.layernorm(lp["norm1"], x), cfg, bits=bits,
            qcfg=qcfg, positions=positions, causal=False)
        x = x + ffn_mod.apply_ffn(lp["ffn"], cm.layernorm(lp["norm2"], x),
                                  bits=bits, qcfg=qcfg, gated=False)
        return x, None

    if cfg.remat:
        body = cm.remat(body, cfg.remat)
    h, _ = cm.scan_layers(body, h, params["encoder"], cfg.unroll_layers)
    return cm.layernorm(params["enc_norm"], h)


def _enc_kv(params, enc_out, cfg, *, bits, qcfg):
    """Precompute per-decoder-layer cross-attention K/V from encoder out."""
    B, T, _ = enc_out.shape
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def body(_, lp):
        ca = lp["cross_attn"]
        k = cm.qlinear(ca["wk"], enc_out, bits=bits, qcfg=qcfg, kind="attn")
        v = cm.qlinear(ca["wv"], enc_out, bits=bits, qcfg=qcfg, kind="attn")
        return None, {"k": k.reshape(B, T, kh, hd), "v": v.reshape(B, T, kh, hd)}

    _, kv = cm.scan_layers(body, None, params["decoder"], cfg.unroll_layers)
    return kv  # leaves stacked (L, B, T, kh, hd)


def forward_encdec(params, frames, tokens, cfg, *, bits=None):
    """Teacher-forced training forward -> (logits (B, S, V), aux=0)."""
    qcfg = cfg.quant
    B, S = tokens.shape
    L = cfg.num_layers
    from repro.models.lm import _bits_per_layer  # shared helper
    bits_l = _bits_per_layer(bits, L)
    enc_out = encode(params, frames, cfg, bits=bits)
    enc_kv = _enc_kv(params, enc_out, cfg, bits=bits, qcfg=qcfg)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h = jnp.take(params["embed"]["w"], tokens, axis=0)

    def body(x, xs):
        lp, kv_l, b = xs
        b = None if bits_l is None else b
        x = x + attn.apply_attention(
            lp["self_attn"], cm.layernorm(lp["norm1"], x), cfg, bits=b,
            qcfg=qcfg, positions=positions, causal=True, chunk=cfg.attn_chunk)
        x = x + _cross_attention(lp["cross_attn"],
                                 cm.layernorm(lp["norm_x"], x), kv_l, cfg,
                                 bits=b, qcfg=qcfg)
        x = x + ffn_mod.apply_ffn(lp["ffn"], cm.layernorm(lp["norm2"], x),
                                  bits=b, qcfg=qcfg, gated=False)
        return x, None

    if cfg.remat:
        body = cm.remat(body, cfg.remat)
    xs = (params["decoder"], enc_kv,
          bits_l if bits_l is not None else jnp.zeros((L,), jnp.int32))
    h, _ = cm.scan_layers(body, h, xs, cfg.unroll_layers)
    h = cm.layernorm(params["final_norm"], h)
    logits = h @ params["embed"]["w"].astype(h.dtype).T
    return cm.constrain(logits, "batch", "seq", "vocab"), jnp.float32(0.0)


def init_encdec_state(cfg, batch: int, max_len: int, frames_shape=None):
    dtype = _dtype(cfg)
    L = cfg.num_layers
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    T = cfg.encoder_len
    return {
        "self_kv": attn.init_cache(cfg, batch, max_len, dtype, layers=L),
        "cross_kv": {
            "k": jnp.zeros((L, batch, T, kh, hd), dtype),
            "v": jnp.zeros((L, batch, T, kh, hd), dtype),
        },
    }


def encdec_state_axes(cfg):
    cross = ("layer", "batch", None, "kv_heads_cache", "head_dim_cache")
    return {"self_kv": attn.cache_axes(layers=True),
            "cross_kv": {"k": cross, "v": cross}}


def prefill_encdec(params, frames, tokens, cfg, *, bits=None, max_len=None):
    """Encode audio + teacher-force the prompt; returns (logits, state)
    with the per-layer self-attention K/V cache populated."""
    qcfg = cfg.quant
    B, S = tokens.shape
    L = cfg.num_layers
    max_len = max_len or S
    from repro.models.lm import _bits_per_layer
    bits_l = _bits_per_layer(bits, L)
    enc_out = encode(params, frames, cfg, bits=bits)
    enc_kv = _enc_kv(params, enc_out, cfg, bits=bits, qcfg=qcfg)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h = jnp.take(params["embed"]["w"], tokens, axis=0)
    dtype = _dtype(cfg)

    def pad_cache(k):
        if max_len == S:
            return k
        pad = jnp.zeros((B, max_len - S) + k.shape[2:], k.dtype)
        return jnp.concatenate([k, pad], axis=1)

    def body(x, xs):
        lp, kv_l, b = xs
        b = None if bits_l is None else b
        xin = cm.layernorm(lp["norm1"], x)
        q, k, v = attn._project_qkv(lp["self_attn"], xin, cfg, bits=b,
                                    qcfg=qcfg, positions=positions)
        o = attn.causal_attention(q, k, v, chunk=cfg.attn_chunk)
        o = o.reshape(B, S, cfg.num_heads * cfg.resolved_head_dim)
        x = x + cm.qlinear(lp["self_attn"]["wo"], o, bits=b, qcfg=qcfg,
                           kind="attn")
        x = x + _cross_attention(lp["cross_attn"],
                                 cm.layernorm(lp["norm_x"], x), kv_l, cfg,
                                 bits=b, qcfg=qcfg)
        x = x + ffn_mod.apply_ffn(lp["ffn"], cm.layernorm(lp["norm2"], x),
                                  bits=b, qcfg=qcfg, gated=False)
        return x, {"k": pad_cache(k).astype(dtype),
                   "v": pad_cache(v).astype(dtype)}

    if cfg.remat:
        body = cm.remat(body, cfg.remat)
    xs = (params["decoder"], enc_kv,
          bits_l if bits_l is not None else jnp.zeros((L,), jnp.int32))
    h, self_kv = cm.scan_layers(body, h, xs, cfg.unroll_layers)
    h = cm.layernorm(params["final_norm"], h)
    logits = h[:, -1:] @ params["embed"]["w"].astype(h.dtype).T
    return logits, {"self_kv": self_kv,
                    "cross_kv": jax.tree.map(lambda a: a.astype(dtype), enc_kv)}


def decode_step_encdec(params, state, token, pos, cfg, *, bits=None):
    """One decode step against self KV cache + fixed cross KV."""
    qcfg = cfg.quant
    B = token.shape[0]
    L = cfg.num_layers
    from repro.models.lm import _bits_per_layer
    bits_l = _bits_per_layer(bits, L)
    h = jnp.take(params["embed"]["w"], token, axis=0)

    def body(x, xs):
        lp, cache_l, cross_l, b = xs
        b = None if bits_l is None else b
        a, new_cache = attn.decode_attention(
            lp["self_attn"], cm.layernorm(lp["norm1"], x), cache_l, pos, cfg,
            bits=b, qcfg=qcfg)
        x = x + a
        x = x + _cross_attention(lp["cross_attn"],
                                 cm.layernorm(lp["norm_x"], x), cross_l, cfg,
                                 bits=b, qcfg=qcfg)
        x = x + ffn_mod.apply_ffn(lp["ffn"], cm.layernorm(lp["norm2"], x),
                                  bits=b, qcfg=qcfg, gated=False)
        return x, new_cache

    xs = (params["decoder"], state["self_kv"], state["cross_kv"],
          bits_l if bits_l is not None else jnp.zeros((L,), jnp.int32))
    h, new_kv = cm.scan_layers(body, h, xs, cfg.unroll_layers)
    h = cm.layernorm(params["final_norm"], h)
    logits = h @ params["embed"]["w"].astype(h.dtype).T
    return logits, {"self_kv": new_kv, "cross_kv": state["cross_kv"]}
