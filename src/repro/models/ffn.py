"""FFN blocks: SwiGLU (LLM default) and GELU MLP (Whisper), plus the
top-k routed MoE with capacity-based static-shape dispatch (TPU-native:
sorted scatter into (E, C, d) buffers feeding one batched einsum on the
MXU, instead of the GPU-style dynamic segment matmuls)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QuantConfig, fake_quant
from repro.models import common as cm


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------


def init_ffn(key, d: int, d_ff: int, qcfg: QuantConfig, dtype=jnp.float32,
             gated: bool = True, bias: bool = False):
    ks = jax.random.split(key, 3)
    p = {
        "up": cm.init_linear(ks[0], d, d_ff, qcfg, kind="ffn", dtype=dtype, bias=bias),
        "down": cm.init_linear(ks[1], d_ff, d, qcfg, kind="ffn", dtype=dtype,
                               bias=bias, scale=d_ff**-0.5),
    }
    if gated:
        p["gate"] = cm.init_linear(ks[2], d, d_ff, qcfg, kind="ffn", dtype=dtype)
    return p


def ffn_axes(gated: bool = True, omn: bool = False, bias: bool = False):
    ax = {
        "up": cm.linear_axes("embed", "mlp", omn=omn, bias=bias),
        "down": cm.linear_axes("mlp", "embed", omn=omn, bias=bias),
    }
    if gated:
        ax["gate"] = cm.linear_axes("embed", "mlp", omn=omn)
    return ax


def apply_ffn(p, x, *, bits, qcfg: QuantConfig, gated: bool = True):
    up = cm.qlinear(p["up"], x, bits=bits, qcfg=qcfg, kind="ffn")
    if gated:
        gate = cm.qlinear(p["gate"], x, bits=bits, qcfg=qcfg, kind="ffn")
        hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        hidden = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    return cm.qlinear(p["down"], hidden, bits=bits, qcfg=qcfg, kind="ffn")


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------


def init_moe(key, d: int, d_ff: int, num_experts: int, qcfg: QuantConfig,
             dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    scale_in, scale_out = d**-0.5, d_ff**-0.5

    def expert_stack(k, d_in, d_out, scale):
        return (
            jax.random.truncated_normal(k, -2.0, 2.0, (num_experts, d_in, d_out)) * scale
        ).astype(dtype)

    return {
        "router": {"w": cm.dense_init(ks[0], d, num_experts, jnp.float32)},
        "up": {"w": expert_stack(ks[1], d, d_ff, scale_in)},
        "gate": {"w": expert_stack(ks[2], d, d_ff, scale_in)},
        "down": {"w": expert_stack(ks[3], d_ff, d, scale_out)},
    }


def moe_axes():
    return {
        "router": {"w": ("embed", None)},  # router stays bf16/fp32 + replicated
        "up": {"w": ("experts", "embed", "expert_mlp")},
        "gate": {"w": ("experts", "embed", "expert_mlp")},
        "down": {"w": ("experts", "expert_mlp", "embed")},
    }


def _expert_plane_matmul(x, plane, *, use_kernel: bool):
    """Batched-over-experts packed matmul for one MoE projection stack.

    x: (B, E, C, d_in); plane: `PackedPlane` with words (E, ..., .) --
    one packed plane per expert, sliced from the stacked parent. Routes
    through kernels.ops.plane_matmul, which grids the Pallas kernel
    over E for K-packed stacks (up/gate) and vmaps the jnp unpack twin
    for N-packed ones (down). Returns (B, E, C, d_out).
    """
    from repro.kernels import ops as _ops
    B, E, C, D = x.shape
    xe = x.transpose(1, 0, 2, 3).reshape(E, B * C, D)
    ye = _ops.plane_matmul(xe, plane, use_kernel=use_kernel)
    return ye.reshape(E, B, C, -1).transpose(1, 0, 2, 3)


def apply_moe(p, x, *, bits, qcfg: QuantConfig, top_k: int,
              capacity_factor: float = 1.25):
    """Top-k routed MoE. x: (B, S, d) -> (B, S, d), plus aux loss.

    ROW-LOCAL sort-based dispatch: routing, sorting, and the capacity
    scatter happen independently per batch row (vmap), so under data
    parallelism no dispatch op ever crosses shards -- the only MoE
    collectives left are the weight/grad reductions. Evolution, driven
    by the roofline (EXPERIMENTS.md §Perf cell B):
      B0 cumsum dispatch, unconstrained  -> einsums replicated (16x
         FLOPs) + O(n^2)-cost reduce-window cumsum;
      B3 global sort dispatch + sharding constraints -> FLOPs fixed but
         the 8.4M-slot global argsort forced cross-shard collectives;
      B4 (this) per-row sort -> dispatch local, capacity per (row,
         expert), einsums batched over the sharded row dim.
    """
    from repro.core.packing import PackedPlane

    B, S, d = x.shape
    E = p["router"]["w"].shape[-1]
    C = max(int(capacity_factor * top_k * S / E), 1)

    def expert_mm(t, proj_p):
        """t (B, E, C, k) @ per-expert weights -> (B, E, C, n), honoring
        each projection's OWN representation: a packed plane routes
        through the batched plane matmul, a raw stack through the
        fake-quant einsum -- mixed layers (e.g. one projection served
        via the dequant fallback) stay servable."""
        w = proj_p["w"]
        if isinstance(w, PackedPlane):
            return _expert_plane_matmul(t, w, use_kernel=qcfg.packed_kernel)
        if bits is not None and qcfg.mode != "bf16":
            # minmax group = the reduction dim (axis 1 of (E, k, n))
            w = fake_quant(w, qcfg.parent_bits, bits, axis=1,
                           extra_precision=qcfg.extra_precision)
        return jnp.einsum("beck,ekn->becn", t, w.astype(t.dtype))

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (B, S, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)          # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    tok_idx = jnp.repeat(jnp.arange(S), top_k)

    def dispatch_row(xr, eidr, gvr):
        """xr: (S, d); eidr/gvr: (S, k) -> scatter into (E, C, d)."""
        n = S * top_k
        eid = eidr.reshape(n)
        order = jnp.argsort(eid, stable=True)
        sorted_eid = eid[order]
        expert_start = jnp.searchsorted(sorted_eid, jnp.arange(E), side="left")
        pos_sorted = jnp.arange(n) - expert_start[sorted_eid]
        inv = jnp.argsort(order, stable=True)
        pos = pos_sorted[inv]
        keep = pos < C
        gv = gvr.reshape(n) * keep.astype(jnp.float32)
        pos_c = jnp.clip(pos, 0, C - 1)
        buf = jnp.zeros((E, C, d), xr.dtype)
        buf = buf.at[eid, pos_c].add(xr[tok_idx] * keep[:, None].astype(xr.dtype))
        return buf, eid, pos_c, gv

    def combine_row(out_buf, eid, pos_c, gv):
        y = out_buf[eid, pos_c] * gv[:, None].astype(out_buf.dtype)
        return jnp.zeros((S, d), out_buf.dtype).at[tok_idx].add(y)

    # dispatch per row (vmap); einsums + sharding constraints OUTSIDE the
    # vmap so the batched buffers keep their 'batch' sharding explicit
    bufs, eids, poss, gvs = jax.vmap(dispatch_row)(x, expert_ids, gate_vals)
    bufs = cm.constrain(bufs, "batch", "experts", None, None)
    up = expert_mm(bufs, p["up"])
    gate = expert_mm(bufs, p["gate"])
    hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    out_bufs = expert_mm(hidden, p["down"])
    out_bufs = cm.constrain(out_bufs, "batch", "experts", None, None)
    out = jax.vmap(combine_row)(out_bufs, eids, poss, gvs)
    out = cm.constrain(out, "batch", "seq", "embed")

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))
    fe = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=2),
        axis=(0, 1))
    aux = E * jnp.sum(me * fe)
    return out, aux
