"""Decoder-only LM assembly: dense / VLM / MoE / xLSTM / Zamba2-hybrid.

Homogeneous stacks (dense, vlm, moe, hybrid-mamba) use scan-over-layers
with stacked params -- one traced block regardless of depth, which keeps
HLO small and compile time flat for the 72B dry-runs. xLSTM (alternating
mLSTM/sLSTM) uses a Python loop (12 layers, heterogeneous blocks).

`bits` is None (bf16), an int, or a per-layer (L,) array (Mix'n'Match);
inside scans it rides along as a scanned input so each layer can be
fake-quantized at its own precision.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QuantConfig
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import ffn as ffn_mod
from repro.models import ssm as ssm_mod


# ---------------------------------------------------------------------------
# init / axes
# ---------------------------------------------------------------------------


def _dtype(cfg):
    return jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32


def init_lm(key, cfg):
    dtype = _dtype(cfg)
    k_embed, k_layers, k_extra, k_head = jax.random.split(key, 4)
    qcfg = cfg.quant
    V = cfg.padded_vocab
    params = {"embed": {"w": cm.embed_init(k_embed, V, cfg.d_model, dtype)},
              "final_norm": cm.init_rmsnorm(cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": cm.dense_init(k_head, cfg.d_model, V, dtype)}

    L = cfg.num_layers
    if cfg.family in ("dense", "vlm"):
        def one(k):
            ka, kf = jax.random.split(k)
            return {
                "norm1": cm.init_rmsnorm(cfg.d_model, dtype),
                "attn": attn.init_attention(ka, cfg, qcfg, dtype),
                "norm2": cm.init_rmsnorm(cfg.d_model, dtype),
                "ffn": ffn_mod.init_ffn(kf, cfg.d_model, cfg.d_ff, qcfg, dtype),
            }
        params["layers"] = jax.vmap(one)(jax.random.split(k_layers, L))
    elif cfg.family == "moe":
        def one(k):
            ka, kf = jax.random.split(k)
            return {
                "norm1": cm.init_rmsnorm(cfg.d_model, dtype),
                "attn": attn.init_attention(ka, cfg, qcfg, dtype),
                "norm2": cm.init_rmsnorm(cfg.d_model, dtype),
                "moe": ffn_mod.init_moe(kf, cfg.d_model, cfg.d_ff,
                                        cfg.num_experts, qcfg, dtype),
            }
        params["layers"] = jax.vmap(one)(jax.random.split(k_layers, L))
    elif cfg.family == "hybrid":
        def one(k):
            return {
                "norm1": cm.init_rmsnorm(cfg.d_model, dtype),
                "mamba": ssm_mod.init_mamba2(k, cfg, qcfg, dtype),
            }
        params["layers"] = jax.vmap(one)(jax.random.split(k_layers, L))
        ka, kf = jax.random.split(k_extra)
        params["shared_attn"] = {
            "norm1": cm.init_rmsnorm(cfg.d_model, dtype),
            "attn": attn.init_attention(ka, cfg, qcfg, dtype),
            "norm2": cm.init_rmsnorm(cfg.d_model, dtype),
            "ffn": ffn_mod.init_ffn(kf, cfg.d_model, cfg.d_ff, qcfg, dtype),
        }
    elif cfg.family == "ssm":  # xLSTM: alternating mLSTM / sLSTM
        layers = []
        for i, k in enumerate(jax.random.split(k_layers, L)):
            if i % 2 == 0:
                layers.append({
                    "norm1": cm.init_rmsnorm(cfg.d_model, dtype),
                    "mlstm": ssm_mod.init_mlstm(k, cfg, qcfg, dtype),
                })
            else:
                layers.append({
                    "norm1": cm.init_rmsnorm(cfg.d_model, dtype),
                    "slstm": ssm_mod.init_slstm(k, cfg, qcfg, dtype),
                })
        params["layers"] = layers
    else:
        raise ValueError(f"init_lm does not handle family {cfg.family!r}")
    return params


def lm_axes(cfg):
    omn = cfg.quant.mode == "omniquant"
    axes = {"embed": {"w": ("vocab", None)},
            "final_norm": {"scale": ("embed",)}}
    if not cfg.tie_embeddings:
        axes["lm_head"] = {"w": (None, "vocab")}

    def stack(block_axes):
        return jax.tree.map(
            lambda t: ("layer",) + t,
            block_axes,
            is_leaf=lambda x: isinstance(x, tuple),
        )

    norm = {"scale": ("embed",)}
    if cfg.family in ("dense", "vlm"):
        block = {"norm1": norm, "attn": attn.attention_axes(cfg, omn),
                 "norm2": norm, "ffn": ffn_mod.ffn_axes(True, omn)}
        axes["layers"] = stack(block)
    elif cfg.family == "moe":
        block = {"norm1": norm, "attn": attn.attention_axes(cfg, omn),
                 "norm2": norm, "moe": ffn_mod.moe_axes()}
        axes["layers"] = stack(block)
    elif cfg.family == "hybrid":
        block = {"norm1": norm, "mamba": ssm_mod.mamba2_axes(omn)}
        axes["layers"] = stack(block)
        axes["shared_attn"] = {"norm1": norm, "attn": attn.attention_axes(cfg, omn),
                               "norm2": norm, "ffn": ffn_mod.ffn_axes(True, omn)}
    elif cfg.family == "ssm":
        layers = []
        for i in range(cfg.num_layers):
            if i % 2 == 0:
                layers.append({"norm1": norm, "mlstm": ssm_mod.mlstm_axes(omn)})
            else:
                layers.append({"norm1": norm, "slstm": ssm_mod.slstm_axes(omn)})
        axes["layers"] = layers
    return axes


# ---------------------------------------------------------------------------
# forward (training / scoring)
# ---------------------------------------------------------------------------


def _bits_per_layer(bits, L):
    """Normalize bits to a scanned (L,) array or None."""
    if bits is None:
        return None
    if isinstance(bits, int):
        return jnp.full((L,), bits, jnp.int32)
    bits = jnp.asarray(bits, jnp.int32)
    if bits.ndim == 0:
        return jnp.broadcast_to(bits, (L,))
    assert bits.shape == (L,), (bits.shape, L)
    return bits


def _embed(params, cfg, tokens, vision_embeds=None):
    h = jnp.take(params["embed"]["w"], tokens, axis=0)
    if vision_embeds is not None:
        nv = vision_embeds.shape[1]
        h = jnp.concatenate([vision_embeds.astype(h.dtype), h[:, nv:]], axis=1)
    return cm.constrain(h, "batch", "seq", "embed")


def _logits(params, cfg, h):
    h = cm.rmsnorm(params["final_norm"], h)
    if cfg.tie_embeddings:
        w = params["embed"]["w"].astype(h.dtype).T
    else:
        w = params["lm_head"]["w"].astype(h.dtype)
    return cm.constrain(h @ w, "batch", "seq", "vocab")


def _dense_block(lp, x, cfg, bits, positions, qcfg, chunk):
    h = x + attn.apply_attention(
        lp["attn"], cm.rmsnorm(lp["norm1"], x), cfg,
        bits=bits, qcfg=qcfg, positions=positions, causal=True, chunk=chunk)
    h = cm.constrain(h, "batch", "seq", "embed")
    out = h + ffn_mod.apply_ffn(lp["ffn"], cm.rmsnorm(lp["norm2"], h),
                                bits=bits, qcfg=qcfg)
    return cm.constrain(out, "batch", "seq", "embed")


def _moe_block(lp, x, cfg, bits, positions, qcfg, chunk):
    h = x + attn.apply_attention(
        lp["attn"], cm.rmsnorm(lp["norm1"], x), cfg,
        bits=bits, qcfg=qcfg, positions=positions, causal=True, chunk=chunk)
    y, aux = ffn_mod.apply_moe(lp["moe"], cm.rmsnorm(lp["norm2"], h),
                               bits=bits, qcfg=qcfg, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor)
    return cm.constrain(h + y, "batch", "seq", "embed"), aux


def forward_lm(params, tokens, cfg, *, bits=None, positions=None,
               vision_embeds=None):
    """tokens: (B, S) int32 -> (logits (B, S, V), aux_loss scalar)."""
    qcfg = cfg.quant
    B, S = tokens.shape
    L = cfg.num_layers
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        if cfg.m_rope:
            positions = jnp.broadcast_to(positions[..., None], (B, S, 3))
    bits_l = _bits_per_layer(bits, L)
    h = _embed(params, cfg, tokens, vision_embeds)
    aux = jnp.float32(0.0)

    if cfg.family in ("dense", "vlm", "moe"):
        is_moe = cfg.family == "moe"

        def body(carry, xs):
            x, aux_acc = carry
            lp, b = xs
            b = None if bits_l is None else b
            if is_moe:
                x, a = _moe_block(lp, x, cfg, b, positions, qcfg, cfg.attn_chunk)
                aux_acc = aux_acc + a
            else:
                x = _dense_block(lp, x, cfg, b, positions, qcfg, cfg.attn_chunk)
            return (x, aux_acc), None

        if cfg.remat:
            body = cm.remat(body, cfg.remat)
        xs = (params["layers"],
              bits_l if bits_l is not None else jnp.zeros((L,), jnp.int32))
        (h, aux), _ = cm.scan_layers(body, (h, aux), xs, cfg.unroll_layers)

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        period = max(cfg.attn_period, 1)

        def body(carry, xs):
            x, aux_acc = carry
            lp, b, idx = xs
            b = None if bits_l is None else b
            x = x + ssm_mod.apply_mamba2(
                lp["mamba"], cm.rmsnorm(lp["norm1"], x), cfg,
                bits=b, qcfg=qcfg, chunk=cfg.ssm_chunk)
            x = cm.constrain(x, "batch", "seq", "embed")

            def with_attn(x):
                return _dense_block(shared, x, cfg, b, positions, qcfg,
                                    cfg.attn_chunk)

            x = jax.lax.cond((idx % period) == period - 1, with_attn,
                             lambda x: x, x)
            return (x, aux_acc), None

        if cfg.remat:
            body = cm.remat(body, cfg.remat)
        xs = (params["layers"],
              bits_l if bits_l is not None else jnp.zeros((L,), jnp.int32),
              jnp.arange(L, dtype=jnp.int32))
        (h, aux), _ = cm.scan_layers(body, (h, aux), xs, cfg.unroll_layers)

    elif cfg.family == "ssm":  # xLSTM, python loop
        def xlstm_block(lp, h, b):
            xin = cm.rmsnorm(lp["norm1"], h)
            if "mlstm" in lp:
                return h + ssm_mod.apply_mlstm(lp["mlstm"], xin, cfg, bits=b,
                                               qcfg=qcfg, chunk=cfg.ssm_chunk)
            y, _ = ssm_mod.apply_slstm(lp["slstm"], xin, cfg, bits=b, qcfg=qcfg)
            return h + y

        if cfg.remat:
            xlstm_block = cm.remat(xlstm_block, cfg.remat)
        for i, lp in enumerate(params["layers"]):
            b = None if bits_l is None else bits_l[i]
            h = xlstm_block(lp, h, b)
    else:
        raise ValueError(cfg.family)

    return _logits(params, cfg, h), aux


# ---------------------------------------------------------------------------
# serving: prefill + decode with stacked caches
# ---------------------------------------------------------------------------


def init_decode_state(cfg, batch: int, max_len: int):
    """Stacked per-layer decode state for the arch family."""
    dtype = _dtype(cfg)
    L = cfg.num_layers
    if cfg.family in ("dense", "vlm", "moe"):
        return {"kv": attn.init_cache(cfg, batch, max_len, dtype, layers=L)}
    if cfg.family == "hybrid":
        return {
            "ssm": ssm_mod.init_mamba2_state(cfg, batch, dtype, layers=L),
            "kv": attn.init_cache(cfg, batch, max_len, dtype, layers=None),
        }
    if cfg.family == "ssm":
        states = {}
        for i in range(L):
            if i % 2 == 0:
                states[f"mlstm_{i}"] = ssm_mod.init_mlstm_state(cfg, batch)
            else:
                states[f"slstm_{i}"] = ssm_mod.init_slstm_state(cfg, batch)
        return states
    raise ValueError(cfg.family)


def decode_state_axes(cfg):
    if cfg.family in ("dense", "vlm", "moe"):
        return {"kv": attn.cache_axes(layers=True)}
    if cfg.family == "hybrid":
        return {"ssm": ssm_mod.mamba2_state_axes(layers=True),
                "kv": attn.cache_axes(layers=False)}
    if cfg.family == "ssm":
        out = {}
        for i in range(cfg.num_layers):
            if i % 2 == 0:
                out[f"mlstm_{i}"] = {"C": ("batch", None, None, None)}
            else:
                out[f"slstm_{i}"] = {k: ("batch", None, None)
                                     for k in ("h", "c", "n", "m")}
        return out
    raise ValueError(cfg.family)


def init_paged_state(cfg, num_pages: int, page_size: int, *, kv_bits=None):
    """Paged decode state: a global page store shared by all slots.

    kv_bits=None keeps full-precision pages (token-identical to the
    dense slot path); an int turns on int8 code pages whose attend view
    is the kv_bits-bit Matryoshka MSB slice. Attention families only --
    the per-slot addressing lives in the scheduler's page table.
    """
    if cfg.family not in ("dense", "vlm", "moe"):
        raise NotImplementedError(
            f"paged KV state requires an attention cache; family "
            f"{cfg.family!r} is served via the dense path")
    return {"kv": attn.init_paged_cache(cfg, num_pages, page_size,
                                        layers=cfg.num_layers,
                                        kv_bits=kv_bits, dtype=_dtype(cfg))}


def paged_state_axes(cfg, kv_bits=None):
    return {"kv": attn.paged_cache_axes(kv_bits is not None, layers=True)}


def prefill_paged(params, tokens, state, ptab, cfg, *, bits=None, last_pos,
                  start=None, kv_bits=None):
    """Prompt processing into the PAGED cache -> (first logits, state).

    Cold admission (start=None) runs the EXACT dense `prefill` graph --
    causal attention over the compact (B, S) prompt block, logits
    gathered at last_pos - 1 -- and then scatters the projected K/V
    rows through each slot's page table, so first-token logits are
    bit-identical to the dense slot path. Prefix-hit admission (start:
    (B,) shared prefix lengths) embeds only the suffix block: rows are
    written at start + j and each query attends causally against the
    gathered page view (shared pages included), which is the verify
    kernel reused as a suffix prefill.
    """
    B, S = tokens.shape
    kv = state["kv"]
    page_size = kv["kp"].shape[2]
    if start is None:
        logits, slot_state = prefill(params, tokens, cfg, bits=bits,
                                     max_len=S, last_pos=last_pos)
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
        pids = jnp.take_along_axis(ptab, positions // page_size, axis=1)
        rows = positions % page_size
        k_new, v_new = slot_state["kv"]["k"], slot_state["kv"]["v"]
        if "ks" not in kv:
            kv = {"kp": kv["kp"].at[:, pids, rows].set(
                      k_new.astype(kv["kp"].dtype), mode="drop"),
                  "vp": kv["vp"].at[:, pids, rows].set(
                      v_new.astype(kv["vp"].dtype), mode="drop")}
        else:
            kq, ka, kb = attn.quant_kv_rows(k_new)
            vq, va, vb = attn.quant_kv_rows(v_new)
            kv = {"kp": kv["kp"].at[:, pids, rows].set(kq, mode="drop"),
                  "vp": kv["vp"].at[:, pids, rows].set(vq, mode="drop"),
                  "ks": kv["ks"].at[:, pids, rows].set(ka, mode="drop"),
                  "kb": kv["kb"].at[:, pids, rows].set(kb, mode="drop"),
                  "vs": kv["vs"].at[:, pids, rows].set(va, mode="drop"),
                  "vb": kv["vb"].at[:, pids, rows].set(vb, mode="drop")}
        return logits, {"kv": kv}
    # prefix hit: suffix-only verify-style prefill at start offsets
    logits_all, state = verify_step_slots(params, state, tokens, start, cfg,
                                          bits=bits, ptab=ptab,
                                          kv_bits=kv_bits)
    idx = jnp.asarray(last_pos, jnp.int32) - 1
    logits = jnp.take_along_axis(logits_all, idx[:, None, None], axis=1)
    return logits, state


def decode_step(params, state, token, pos, cfg, *, bits=None):
    """One decoding step. token: (B, 1) int32; pos: scalar int32 index.

    Returns (logits (B, 1, V), new state). Lowered by the decode_32k /
    long_500k dry-run cells.
    """
    qcfg = cfg.quant
    B = token.shape[0]
    L = cfg.num_layers
    bits_l = _bits_per_layer(bits, L)
    h = jnp.take(params["embed"]["w"], token, axis=0)
    h = cm.constrain(h, "batch", None, "embed")

    if cfg.family in ("dense", "vlm", "moe"):
        is_moe = cfg.family == "moe"

        def body(x, xs):
            lp, cache_l, b = xs
            b = None if bits_l is None else b
            a, new_cache = attn.decode_attention(
                lp["attn"], cm.rmsnorm(lp["norm1"], x), cache_l, pos, cfg,
                bits=b, qcfg=qcfg)
            x = x + a
            if is_moe:
                y, _ = ffn_mod.apply_moe(lp["moe"], cm.rmsnorm(lp["norm2"], x),
                                         bits=b, qcfg=qcfg, top_k=cfg.top_k,
                                         capacity_factor=cfg.capacity_factor)
            else:
                y = ffn_mod.apply_ffn(lp["ffn"], cm.rmsnorm(lp["norm2"], x),
                                      bits=b, qcfg=qcfg)
            return x + y, new_cache

        xs = (params["layers"], state["kv"],
              bits_l if bits_l is not None else jnp.zeros((L,), jnp.int32))
        h, new_kv = cm.scan_layers(body, h, xs, cfg.unroll_layers)
        return _logits(params, cfg, h), {"kv": new_kv}

    if cfg.family == "hybrid":
        shared = params["shared_attn"]
        period = max(cfg.attn_period, 1)
        kv = state["kv"]

        def body(carry, xs):
            x, kv_c = carry
            lp, st_l, b, idx = xs
            b = None if bits_l is None else b
            y, st_new = ssm_mod.decode_mamba2(
                lp["mamba"], cm.rmsnorm(lp["norm1"], x), st_l, cfg,
                bits=b, qcfg=qcfg)
            x = x + y

            def with_attn(args):
                x, kv_c = args
                a, kv_new = attn.decode_attention(
                    shared["attn"], cm.rmsnorm(shared["norm1"], x), kv_c,
                    pos, cfg, bits=b, qcfg=qcfg)
                x = x + a
                x = x + ffn_mod.apply_ffn(
                    shared["ffn"], cm.rmsnorm(shared["norm2"], x),
                    bits=b, qcfg=qcfg)
                return x, kv_new

            x, kv_c = jax.lax.cond(
                (idx % period) == period - 1, with_attn, lambda a: a, (x, kv_c))
            return (x, kv_c), st_new

        xs = (params["layers"], state["ssm"],
              bits_l if bits_l is not None else jnp.zeros((L,), jnp.int32),
              jnp.arange(L, dtype=jnp.int32))
        (h, kv_new), ssm_new = cm.scan_layers(body, (h, kv), xs, cfg.unroll_layers)
        return _logits(params, cfg, h), {"ssm": ssm_new, "kv": kv_new}

    if cfg.family == "ssm":
        new_state = {}
        for i, lp in enumerate(params["layers"]):
            b = None if bits_l is None else bits_l[i]
            xin = cm.rmsnorm(lp["norm1"], h)
            if "mlstm" in lp:
                y, st = ssm_mod.decode_mlstm(lp["mlstm"], xin,
                                             state[f"mlstm_{i}"], cfg,
                                             bits=b, qcfg=qcfg)
                new_state[f"mlstm_{i}"] = st
            else:
                y, st = ssm_mod.decode_slstm(lp["slstm"], xin,
                                             state[f"slstm_{i}"], cfg,
                                             bits=b, qcfg=qcfg)
                new_state[f"slstm_{i}"] = st
            h = h + y
        return _logits(params, cfg, h), new_state

    raise ValueError(cfg.family)


def decode_step_slots(params, state, token, pos, cfg, *, bits=None,
                      ptab=None, kv_bits=None, attn_kernel: str = "fused"):
    """One decode step over a SLOT ARRAY with per-slot positions.

    token: (B, 1) int32; pos: (B,) int32, each slot's current write
    index. Returns (logits (B, 1, V), new state). This is the inner step
    of the continuous-batching scheduler: the batch axis is a fixed slot
    array (static shapes, one compile), rows belong to different requests
    at different decode depths, and inactive slots just compute garbage
    that the scheduler masks at the bookkeeping level.

    With `ptab` (a (B, pages_per_slot) page table) the state is the
    PAGED cache from `init_paged_state`: each layer writes/attends
    through the page table instead of a dense per-slot array, and
    `kv_bits` picks the r-bit Matryoshka attend view of the stored int8
    codes (None = full precision pages), and `attn_kernel` (static) the
    paged read path -- "fused" attends straight off the page store via
    the Pallas kernel, "gather" keeps the gather+dequant fallback.

    Supported for attention-cache families (dense / vlm / moe); the
    recurrent families keep the shared-position `decode_step` path.
    """
    qcfg = cfg.quant
    L = cfg.num_layers
    if cfg.family not in ("dense", "vlm", "moe"):
        raise NotImplementedError(
            f"slot-wise decode requires an attention KV cache; family "
            f"{cfg.family!r} is served via the legacy shared-position path")
    bits_l = _bits_per_layer(bits, L)
    h = jnp.take(params["embed"]["w"], token, axis=0)
    h = cm.constrain(h, "batch", None, "embed")
    is_moe = cfg.family == "moe"

    def body(x, xs):
        lp, cache_l, b = xs
        b = None if bits_l is None else b
        if ptab is None:
            a, new_cache = attn.decode_attention_slots(
                lp["attn"], cm.rmsnorm(lp["norm1"], x), cache_l, pos, cfg,
                bits=b, qcfg=qcfg)
        else:
            a, new_cache = attn.paged_decode_attention_slots(
                lp["attn"], cm.rmsnorm(lp["norm1"], x), cache_l, ptab, pos,
                cfg, bits=b, qcfg=qcfg, kv_bits=kv_bits,
                attn_kernel=attn_kernel)
        x = x + a
        if is_moe:
            y, _ = ffn_mod.apply_moe(lp["moe"], cm.rmsnorm(lp["norm2"], x),
                                     bits=b, qcfg=qcfg, top_k=cfg.top_k,
                                     capacity_factor=cfg.capacity_factor)
        else:
            y = ffn_mod.apply_ffn(lp["ffn"], cm.rmsnorm(lp["norm2"], x),
                                  bits=b, qcfg=qcfg)
        return x + y, new_cache

    xs = (params["layers"], state["kv"],
          bits_l if bits_l is not None else jnp.zeros((L,), jnp.int32))
    h, new_kv = cm.scan_layers(body, h, xs, cfg.unroll_layers)
    return _logits(params, cfg, h), {"kv": new_kv}


def verify_step_slots(params, state, tokens, pos, cfg, *, bits=None,
                      ptab=None, kv_bits=None):
    """Score T tokens per slot in ONE step (spec-decode verification).

    tokens: (B, T) int32 -- slot b's draft block [d_0 .. d_{T-1}]; pos:
    (B,) int32, the cache position of d_0 (the verified last token).
    Returns (logits (B, T, V), new state): logits[:, j] scores position
    pos + j having attended to tokens[:, :j+1] plus the committed
    prefix, so argmax(logits[:, j]) is exactly what a sequential
    `decode_step_slots` chain would predict after token j -- the greedy
    acceptance oracle. KV rows pos..pos+T-1 are written; rows past the
    accepted prefix are stale afterwards and the scheduler rolls them
    back (`serve.kv_cache.rollback_slots`).

    A T=1 call is `decode_step_slots` exactly (same einsums, same
    reduction shapes). MoE layers get a capacity floor so the T-row
    verify block never drops tokens that the one-row decode would route
    (C scales with rows; dispatch stays row-local, so slots remain
    independent).
    """
    qcfg = cfg.quant
    L = cfg.num_layers
    if cfg.family not in ("dense", "vlm", "moe"):
        raise NotImplementedError(
            f"slot-wise verify requires an attention KV cache; family "
            f"{cfg.family!r} is served via the legacy shared-position path")
    bits_l = _bits_per_layer(bits, L)
    h = jnp.take(params["embed"]["w"], tokens, axis=0)
    h = cm.constrain(h, "batch", None, "embed")
    is_moe = cfg.family == "moe"
    if is_moe:
        # C = max(int(cf * top_k * S / E), 1) rows per expert: floor cf
        # at E / top_k so C >= S and the verify block drops nothing.
        cap = max(float(cfg.capacity_factor), cfg.num_experts / cfg.top_k)

    def body(x, xs):
        lp, cache_l, b = xs
        b = None if bits_l is None else b
        if ptab is None:
            a, new_cache = attn.verify_attention_slots(
                lp["attn"], cm.rmsnorm(lp["norm1"], x), cache_l, pos, cfg,
                bits=b, qcfg=qcfg)
        else:
            a, new_cache = attn.paged_verify_attention_slots(
                lp["attn"], cm.rmsnorm(lp["norm1"], x), cache_l, ptab, pos,
                cfg, bits=b, qcfg=qcfg, kv_bits=kv_bits)
        x = x + a
        if is_moe:
            y, _ = ffn_mod.apply_moe(lp["moe"], cm.rmsnorm(lp["norm2"], x),
                                     bits=b, qcfg=qcfg, top_k=cfg.top_k,
                                     capacity_factor=cap)
        else:
            y = ffn_mod.apply_ffn(lp["ffn"], cm.rmsnorm(lp["norm2"], x),
                                  bits=b, qcfg=qcfg)
        return x + y, new_cache

    xs = (params["layers"], state["kv"],
          bits_l if bits_l is not None else jnp.zeros((L,), jnp.int32))
    h, new_kv = cm.scan_layers(body, h, xs, cfg.unroll_layers)
    return _logits(params, cfg, h), {"kv": new_kv}


def prefill(params, tokens, cfg, *, bits=None, max_len=None,
            positions=None, vision_embeds=None, last_pos=None):
    """Process a full prompt; returns (last-position logits, decode state).

    For attention families the KV cache is materialized from the
    projected k/v of the forward pass (padded to max_len); for SSM
    families the final recurrent state is returned.

    `last_pos` (may be traced): position count of the REAL prompt when
    `tokens` is right-padded to a static bucket; logits are gathered at
    index last_pos - 1 instead of -1. A scalar applies one length to the
    whole batch; a (B,) vector gathers per row -- the batched-admission
    path, where one prefill call seats several requests of different
    prompt lengths padded to the same bucket. Under causal attention
    right-padding is exact -- pad positions never influence logits at
    earlier positions, and their (garbage) KV rows are overwritten by
    decode steps before ever entering an attention window. Recurrent
    families fold pad tokens into their state, so only pass last_pos for
    attention families.
    """
    qcfg = cfg.quant
    B, S = tokens.shape
    L = cfg.num_layers
    max_len = max_len or S
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        if cfg.m_rope:
            positions = jnp.broadcast_to(positions[..., None], (B, S, 3))
    bits_l = _bits_per_layer(bits, L)
    h = _embed(params, cfg, tokens, vision_embeds)

    def last(h):
        if last_pos is None:
            return h[:, -1:]
        idx = jnp.asarray(last_pos, jnp.int32) - 1
        if idx.ndim == 0:
            return jax.lax.dynamic_slice_in_dim(h, idx, 1, axis=1)
        return jnp.take_along_axis(h, idx[:, None, None], axis=1)

    def pad_cache(k):
        if max_len == S:
            return k
        pad = jnp.zeros((B, max_len - S) + k.shape[2:], k.dtype)
        return jnp.concatenate([k, pad], axis=1)

    if cfg.family in ("dense", "vlm", "moe"):
        is_moe = cfg.family == "moe"

        def body(x, xs):
            lp, b = xs
            b = None if bits_l is None else b
            xin = cm.rmsnorm(lp["norm1"], x)
            q, k, v = attn._project_qkv(lp["attn"], xin, cfg, bits=b,
                                        qcfg=qcfg, positions=positions)
            o = attn.causal_attention(q, k, v, chunk=cfg.attn_chunk)
            o = o.reshape(B, S, cfg.num_heads * cfg.resolved_head_dim)
            x = x + cm.qlinear(lp["attn"]["wo"], o, bits=b, qcfg=qcfg, kind="attn")
            if is_moe:
                y, _ = ffn_mod.apply_moe(lp["moe"], cm.rmsnorm(lp["norm2"], x),
                                         bits=b, qcfg=qcfg, top_k=cfg.top_k,
                                         capacity_factor=cfg.capacity_factor)
            else:
                y = ffn_mod.apply_ffn(lp["ffn"], cm.rmsnorm(lp["norm2"], x),
                                      bits=b, qcfg=qcfg)
            dtype = _dtype(cfg)
            return x + y, {"k": pad_cache(k).astype(dtype),
                           "v": pad_cache(v).astype(dtype)}

        if cfg.remat:
            body = cm.remat(body, cfg.remat)
        xs = (params["layers"],
              bits_l if bits_l is not None else jnp.zeros((L,), jnp.int32))
        h, kv = cm.scan_layers(body, h, xs, cfg.unroll_layers)
        return _logits(params, cfg, last(h)), {"kv": kv}

    if cfg.family in ("hybrid", "ssm"):
        # run the training forward but thread/collect final states
        state = init_decode_state(cfg, B, max_len)
        if cfg.family == "hybrid":
            shared = params["shared_attn"]
            period = max(cfg.attn_period, 1)
            kv = state["kv"]

            def body(carry, xs):
                x, kv_c = carry
                lp, b, idx = xs
                b = None if bits_l is None else b
                xin = cm.rmsnorm(lp["norm1"], x)
                z, xi, bv, cv, dt, d_inner, N, H = ssm_mod._mamba2_proj(
                    lp["mamba"], xin, cfg, bits=b, qcfg=qcfg)
                xbc, conv_buf = ssm_mod._causal_conv(
                    jnp.concatenate([xi, bv, cv], axis=-1), lp["mamba"]["conv_w"])
                xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
                xi, bv, cv = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
                P = d_inner // H
                xi = xi.reshape(B, S, H, P)
                bh = jnp.broadcast_to(bv[:, :, None, :], (B, S, H, N))
                ch = jnp.broadcast_to(cv[:, :, None, :], (B, S, H, N))
                dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["mamba"]["dt_bias"])
                dA = dt * (-jnp.exp(lp["mamba"]["A_log"]))
                y, h_fin = ssm_mod.ssd_chunked(xi, bh, ch, dA, dt,
                                               chunk=min(cfg.ssm_chunk, S))
                y = y + lp["mamba"]["D"][None, None, :, None] * xi.astype(jnp.float32)
                y = y.reshape(B, S, d_inner).astype(x.dtype)
                y = cm.rmsnorm(lp["mamba"]["norm"],
                               y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype))
                x = x + cm.qlinear(lp["mamba"]["wo"], y, bits=b, qcfg=qcfg, kind="ffn")

                def with_attn(args):
                    x, kv_c = args
                    xin2 = cm.rmsnorm(shared["norm1"], x)
                    q, k, v = attn._project_qkv(shared["attn"], xin2, cfg,
                                                bits=b, qcfg=qcfg,
                                                positions=positions)
                    o = attn.causal_attention(q, k, v, chunk=cfg.attn_chunk)
                    o = o.reshape(B, S, cfg.num_heads * cfg.resolved_head_dim)
                    x = x + cm.qlinear(shared["attn"]["wo"], o, bits=b,
                                       qcfg=qcfg, kind="attn")
                    x = x + ffn_mod.apply_ffn(
                        shared["ffn"], cm.rmsnorm(shared["norm2"], x),
                        bits=b, qcfg=qcfg)
                    dtype = _dtype(cfg)
                    return x, {"k": pad_cache(k).astype(dtype),
                               "v": pad_cache(v).astype(dtype)}

                x, kv_c = jax.lax.cond(
                    (idx % period) == period - 1, with_attn, lambda a: a,
                    (x, kv_c))
                # conv_buf holds the last k-1 *pre-conv* inputs -- exactly
                # what decode_mamba2 expects as its rolling buffer.
                st = {"h": h_fin, "conv": conv_buf.astype(_dtype(cfg))}
                return (x, kv_c), st

            xs = (params["layers"],
                  bits_l if bits_l is not None else jnp.zeros((L,), jnp.int32),
                  jnp.arange(L, dtype=jnp.int32))
            (h, kv_new), ssm_new = cm.scan_layers(body, (h, kv), xs, cfg.unroll_layers)
            return _logits(params, cfg, last(h)), {"ssm": ssm_new, "kv": kv_new}

        # xLSTM prefill: python loop, collect states
        new_state = {}
        for i, lp in enumerate(params["layers"]):
            b = None if bits_l is None else bits_l[i]
            xin = cm.rmsnorm(lp["norm1"], h)
            if "mlstm" in lp:
                q, k, v, ig, f, H, dh = ssm_mod._mlstm_qkv(lp["mlstm"], xin, cfg,
                                                           bits=b, qcfg=qcfg)
                v_aug = jnp.concatenate(
                    [v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], axis=-1)
                y_aug, C_fin = ssm_mod.ssd_chunked(v_aug, k, q, f, ig,
                                                   chunk=min(cfg.ssm_chunk, S))
                y = ssm_mod._mlstm_norm_out(lp["mlstm"], y_aug, None, xin, dh,
                                            bits=b, qcfg=qcfg)
                new_state[f"mlstm_{i}"] = {"C": C_fin}
            else:
                y, st = ssm_mod.apply_slstm(lp["slstm"], xin, cfg, bits=b, qcfg=qcfg)
                new_state[f"slstm_{i}"] = st
            h = h + y
        return _logits(params, cfg, last(h)), new_state

    raise ValueError(cfg.family)
