"""State-space / recurrent blocks: Mamba2 (SSD), mLSTM, sLSTM.

One generic chunked SSD primitive serves both Mamba2 and mLSTM -- they
share the recurrence  h_t = exp(dA_t) * h_t-1 + g_t * (b_t  v_t^T),
y_t = c_t . h_t,  differing only in how (dA, g, b, c, v) are produced.
The chunked form (intra-chunk masked matmul + inter-chunk lax.scan) is
MXU-friendly: all heavy math is batched matmuls; only the tiny per-chunk
state recurrence is sequential.

sLSTM is a true recurrence (h feeds back through per-head R matrices)
and is computed with a lax.scan over time.

Quantization: the parameter-heavy in/out projections route through
`qlinear` (scope 'ffn' -- see DESIGN.md on arch applicability); the
small, sensitive state parameters (A_log, dt_bias, conv, gates' R)
stay full precision, mirroring the paper quantizing only FFN weights.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QuantConfig
from repro.models import common as cm


# ---------------------------------------------------------------------------
# Generic chunked SSD
# ---------------------------------------------------------------------------


def ssd_chunked(v, b, c, dA, g, chunk: int = 128, h0=None):
    """Chunked linear-recurrent attention.

    v: (B, T, H, P) values;  b: (B, T, H, N) input keys;
    c: (B, T, H, N) output queries;  dA: (B, T, H) log-decay (<= 0);
    g: (B, T, H) input gate (dt for Mamba2, i for mLSTM).
    Returns (y: (B, T, H, P), h_final: (B, H, N, P)).
    """
    B, T, H, P = v.shape
    N = b.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nc, Q = T // chunk, chunk
    rs = lambda a: a.reshape((B, nc, Q) + a.shape[2:])
    v, b, c, dA, g = map(rs, (v, b, c, dA, g))
    dA = dA.astype(jnp.float32)
    g = g.astype(jnp.float32)

    cum = jnp.cumsum(dA, axis=2)                            # (B,nc,Q,H)
    # intra-chunk: scores[t,s] = (c_t . b_s) * exp(cum_t - cum_s) * g_s, s<=t
    L = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # (B,nc,Q,S,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    L = jnp.where(mask, L, -jnp.inf)
    qk = jnp.einsum("bcqhn,bcshn->bcqsh", c.astype(jnp.float32), b.astype(jnp.float32))
    scores = qk * jnp.exp(L) * g[:, :, None, :, :]
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", scores, v.astype(jnp.float32))

    # per-chunk state contribution and decay
    tail = cum[:, :, -1:, :] - cum                          # (B,nc,Q,H) >= 0? no: <=0 negated
    w = jnp.exp(tail) * g                                   # weight of step s into chunk state
    S_c = jnp.einsum("bcsh,bcshn,bcshp->bchnp", w, b.astype(jnp.float32),
                     v.astype(jnp.float32))
    G_c = jnp.exp(cum[:, :, -1, :])                         # (B,nc,H)

    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)

    def step(h, inputs):
        s_c, g_c = inputs
        h_new = g_c[:, :, None, None] * h + s_c
        return h_new, h  # emit the PRE-update state for inter-chunk reads

    (h_final, h_prevs) = jax.lax.scan(
        step,
        h0.astype(jnp.float32),
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(G_c, 1, 0)),
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                   # (B,nc,H,N,P)

    y_inter = jnp.einsum(
        "bcqhn,bchnp->bcqhp", (c.astype(jnp.float32) * jnp.exp(cum)[..., None]), h_prevs
    )
    y = (y_intra + y_inter).reshape(B, T, H, P)
    return y, h_final


def ssd_decode_step(h, v, b, c, dA, g):
    """Single-token recurrence. h: (B,H,N,P); v:(B,H,P); b,c:(B,H,N);
    dA,g:(B,H). Returns (y: (B,H,P), h_new)."""
    h_new = jnp.exp(dA.astype(jnp.float32))[:, :, None, None] * h + (
        g.astype(jnp.float32)[:, :, None, None]
        * b.astype(jnp.float32)[:, :, :, None]
        * v.astype(jnp.float32)[:, :, None, :]
    )
    y = jnp.einsum("bhn,bhnp->bhp", c.astype(jnp.float32), h_new)
    return y, h_new


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def _causal_conv(x, w, buf=None):
    """Depthwise causal conv. x: (B, T, C); w: (k, C). If buf (B, k-1, C)
    is given (decode), prepend it; else left-pad zeros."""
    k = w.shape[0]
    if buf is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = buf.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(k))
    new_buf = xp[:, -(k - 1):] if k > 1 else None
    return out, new_buf


def init_mamba2(key, cfg, qcfg: QuantConfig, dtype=jnp.float32):
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    H, N, k = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_conv
    ks = jax.random.split(key, 8)
    conv_ch = d_inner + 2 * N  # x, B, C share the conv (G=1 groups)
    return {
        "wz": cm.init_linear(ks[0], d, d_inner, qcfg, kind="ffn", dtype=dtype),
        "wx": cm.init_linear(ks[1], d, d_inner, qcfg, kind="ffn", dtype=dtype),
        "wB": {"w": cm.dense_init(ks[2], d, N, dtype)},
        "wC": {"w": cm.dense_init(ks[3], d, N, dtype)},
        "wdt": {"w": cm.dense_init(ks[4], d, H, dtype)},
        "wo": cm.init_linear(ks[5], d_inner, d, qcfg, kind="ffn", dtype=dtype,
                             scale=d_inner**-0.5),
        "conv_w": (jax.random.normal(ks[6], (k, conv_ch)) * 0.1).astype(dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),  # softplus(-2) ~ 0.13
        "norm": cm.init_rmsnorm(d_inner, dtype),
    }


def mamba2_axes(omn: bool = False):
    return {
        "wz": cm.linear_axes("embed", "inner", omn=omn),
        "wx": cm.linear_axes("embed", "inner", omn=omn),
        "wB": {"w": ("embed", None)},
        "wC": {"w": ("embed", None)},
        "wdt": {"w": ("embed", None)},
        "wo": cm.linear_axes("inner", "embed", omn=omn),
        "conv_w": (None, "inner"),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm": {"scale": ("inner",)},
    }


def _mamba2_proj(p, u, cfg, *, bits, qcfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    N, H = cfg.ssm_state, cfg.ssm_heads
    z = cm.qlinear(p["wz"], u, bits=bits, qcfg=qcfg, kind="ffn")
    x = cm.qlinear(p["wx"], u, bits=bits, qcfg=qcfg, kind="ffn")
    bv = u @ p["wB"]["w"].astype(u.dtype)
    cv = u @ p["wC"]["w"].astype(u.dtype)
    dt = u @ p["wdt"]["w"].astype(u.dtype)
    return z, x, bv, cv, dt, d_inner, N, H


def apply_mamba2(p, u, cfg, *, bits, qcfg: QuantConfig, chunk: int = 128):
    """Training/prefill. u: (B, T, d) -> (B, T, d)."""
    B, T, d = u.shape
    z, x, bv, cv, dt, d_inner, N, H = _mamba2_proj(p, u, cfg, bits=bits, qcfg=qcfg)
    xbc, _ = _causal_conv(jnp.concatenate([x, bv, cv], axis=-1), p["conv_w"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(u.dtype)
    x, bv, cv = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    P = d_inner // H
    x = x.reshape(B, T, H, P)
    bh = jnp.broadcast_to(bv[:, :, None, :], (B, T, H, N))
    ch = jnp.broadcast_to(cv[:, :, None, :], (B, T, H, N))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dA = dt * (-jnp.exp(p["A_log"]))                      # (B,T,H), <= 0
    y, _ = ssd_chunked(x, bh, ch, dA, dt, chunk=min(chunk, T))
    y = y + p["D"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(B, T, d_inner).astype(u.dtype)
    y = cm.rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype))
    return cm.qlinear(p["wo"], y, bits=bits, qcfg=qcfg, kind="ffn")


def init_mamba2_state(cfg, batch: int, dtype=jnp.float32, layers: int | None = None):
    d_inner = cfg.ssm_expand * cfg.d_model
    H, N, k = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_conv
    P = d_inner // H
    conv_ch = d_inner + 2 * N
    h = (batch, H, N, P)
    cb = (batch, k - 1, conv_ch)
    if layers is not None:
        h, cb = (layers,) + h, (layers,) + cb
    return {"h": jnp.zeros(h, jnp.float32), "conv": jnp.zeros(cb, dtype)}


def mamba2_state_axes(layers: bool = True):
    h = ("batch", "heads_cache", None, None)
    cb = ("batch", None, "inner")
    if layers:
        h, cb = ("layer",) + h, ("layer",) + cb
    return {"h": h, "conv": cb}


def decode_mamba2(p, u, state, cfg, *, bits, qcfg: QuantConfig):
    """One-token decode. u: (B, 1, d); state {'h','conv'}."""
    B = u.shape[0]
    z, x, bv, cv, dt, d_inner, N, H = _mamba2_proj(p, u, cfg, bits=bits, qcfg=qcfg)
    xbc, new_conv = _causal_conv(
        jnp.concatenate([x, bv, cv], axis=-1), p["conv_w"], buf=state["conv"]
    )
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(u.dtype)
    x, bv, cv = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    P = d_inner // H
    x1 = x.reshape(B, H, P)
    b1 = jnp.broadcast_to(bv.reshape(B, 1, N), (B, H, N))
    c1 = jnp.broadcast_to(cv.reshape(B, 1, N), (B, H, N))
    dt1 = jax.nn.softplus(dt.reshape(B, H).astype(jnp.float32) + p["dt_bias"])
    dA1 = dt1 * (-jnp.exp(p["A_log"]))
    y, h_new = ssd_decode_step(state["h"], x1, b1, c1, dA1, dt1)
    y = y + p["D"][None, :, None] * x1.astype(jnp.float32)
    y = y.reshape(B, 1, d_inner).astype(u.dtype)
    y = cm.rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype))
    out = cm.qlinear(p["wo"], y, bits=bits, qcfg=qcfg, kind="ffn")
    return out, {"h": h_new, "conv": new_conv.astype(state["conv"].dtype)}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM) -- matrix memory with scalar gates; parallel via SSD.
# Simplification noted in DESIGN.md: input gate uses 2*sigmoid instead of
# the stabilized exponential gate (the projections, which MatQuant
# quantizes, are unchanged).
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg, qcfg: QuantConfig, dtype=jnp.float32):
    d, H = cfg.d_model, cfg.num_heads
    dh = d // H
    ks = jax.random.split(key, 7)
    return {
        "wq": cm.init_linear(ks[0], d, d, qcfg, kind="ffn", dtype=dtype),
        "wk": cm.init_linear(ks[1], d, d, qcfg, kind="ffn", dtype=dtype),
        "wv": cm.init_linear(ks[2], d, d, qcfg, kind="ffn", dtype=dtype),
        "wi": {"w": cm.dense_init(ks[3], d, H, dtype)},
        "wf": {"w": cm.dense_init(ks[4], d, H, dtype)},
        "wo": cm.init_linear(ks[5], d, d, qcfg, kind="ffn", dtype=dtype),
        "f_bias": jnp.full((H,), 3.0, jnp.float32),  # open forget gate at init
        "norm": cm.init_rmsnorm(d, dtype),
    }


def mlstm_axes(omn: bool = False):
    return {
        "wq": cm.linear_axes("embed", "inner", omn=omn),
        "wk": cm.linear_axes("embed", "inner", omn=omn),
        "wv": cm.linear_axes("embed", "inner", omn=omn),
        "wi": {"w": ("embed", None)},
        "wf": {"w": ("embed", None)},
        "wo": cm.linear_axes("inner", "embed", omn=omn),
        "f_bias": (None,),
        "norm": {"scale": ("inner",)},
    }


def _mlstm_qkv(p, u, cfg, *, bits, qcfg):
    B, T, d = u.shape
    H = cfg.num_heads
    dh = d // H
    q = cm.qlinear(p["wq"], u, bits=bits, qcfg=qcfg, kind="ffn").reshape(B, T, H, dh)
    k = cm.qlinear(p["wk"], u, bits=bits, qcfg=qcfg, kind="ffn").reshape(B, T, H, dh)
    v = cm.qlinear(p["wv"], u, bits=bits, qcfg=qcfg, kind="ffn").reshape(B, T, H, dh)
    i = 2.0 * jax.nn.sigmoid((u @ p["wi"]["w"].astype(u.dtype)).astype(jnp.float32))
    f = jax.nn.log_sigmoid(
        (u @ p["wf"]["w"].astype(u.dtype)).astype(jnp.float32) + p["f_bias"]
    )
    k = k * (dh**-0.5)
    return q, k, v, i, f, H, dh


def _mlstm_norm_out(p, y_aug, z_gate, u, dh, *, bits, qcfg):
    B, T = y_aug.shape[:2]
    y, n = y_aug[..., :dh], y_aug[..., dh:]
    y = y / jnp.maximum(jnp.abs(n), 1.0)
    y = y.reshape(B, T, -1).astype(u.dtype)
    y = cm.rmsnorm(p["norm"], y)
    return cm.qlinear(p["wo"], y, bits=bits, qcfg=qcfg, kind="ffn")


def apply_mlstm(p, u, cfg, *, bits, qcfg: QuantConfig, chunk: int = 128):
    B, T, d = u.shape
    q, k, v, i, f, H, dh = _mlstm_qkv(p, u, cfg, bits=bits, qcfg=qcfg)
    # augment v with ones to carry the normalizer through the same SSD
    v_aug = jnp.concatenate([v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], axis=-1)
    y_aug, _ = ssd_chunked(v_aug, k, q, f, i, chunk=min(chunk, T))
    return _mlstm_norm_out(p, y_aug, None, u, dh, bits=bits, qcfg=qcfg)


def init_mlstm_state(cfg, batch: int, layers: int | None = None):
    H = cfg.num_heads
    dh = cfg.d_model // H
    shape = (batch, H, dh, dh + 1)
    if layers is not None:
        shape = (layers,) + shape
    return {"C": jnp.zeros(shape, jnp.float32)}


def decode_mlstm(p, u, state, cfg, *, bits, qcfg: QuantConfig):
    B = u.shape[0]
    q, k, v, i, f, H, dh = _mlstm_qkv(p, u, cfg, bits=bits, qcfg=qcfg)
    v_aug = jnp.concatenate([v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], axis=-1)
    y_aug, C_new = ssd_decode_step(
        state["C"], v_aug[:, 0], k[:, 0], q[:, 0], f[:, 0], i[:, 0]
    )
    out = _mlstm_norm_out(p, y_aug[:, None], None, u, dh, bits=bits, qcfg=qcfg)
    return out, {"C": C_new}


# ---------------------------------------------------------------------------
# sLSTM -- scalar memory, true recurrence through per-head R matrices.
# ---------------------------------------------------------------------------


def init_slstm(key, cfg, qcfg: QuantConfig, dtype=jnp.float32):
    d, H = cfg.d_model, cfg.num_heads
    dh = d // H
    ks = jax.random.split(key, 3)
    return {
        "wx": cm.init_linear(ks[0], d, 4 * d, qcfg, kind="ffn", dtype=dtype),
        "r": (jax.random.normal(ks[1], (H, dh, 4 * dh)) * dh**-0.5).astype(dtype),
        "wo": cm.init_linear(ks[2], d, d, qcfg, kind="ffn", dtype=dtype),
        "norm": cm.init_rmsnorm(d, dtype),
    }


def slstm_axes(omn: bool = False):
    return {
        "wx": cm.linear_axes("embed", "inner", omn=omn),
        "r": (None, None, None),
        "wo": cm.linear_axes("inner", "embed", omn=omn),
        "norm": {"scale": ("embed",)},
    }


def init_slstm_state(cfg, batch: int, layers: int | None = None):
    H = cfg.num_heads
    dh = cfg.d_model // H
    s = (batch, H, dh)
    if layers is not None:
        s = (layers,) + s
    z = lambda: jnp.zeros(s, jnp.float32)
    return {"h": z(), "c": z(), "n": z(), "m": z()}


def _slstm_cell(state, gx, r):
    """One timestep. gx: (B, 4*d) preactivations from input;
    r: (H, dh, 4*dh) recurrent weights; state leaves (B, H, dh)."""
    h, c, n, m = state["h"], state["c"], state["n"], state["m"]
    B, H, dh = h.shape
    gr = jnp.einsum("bhd,hdk->bhk", h, r.astype(jnp.float32))   # (B,H,4*dh)
    g = gx.reshape(B, H, 4 * dh).astype(jnp.float32) + gr
    it, ft, zt, ot = jnp.split(g, 4, axis=-1)
    zt = jnp.tanh(zt)
    ot = jax.nn.sigmoid(ot)
    ft = jax.nn.log_sigmoid(ft)                                  # log forget
    m_new = jnp.maximum(ft + m, it)
    i_s = jnp.exp(it - m_new)
    f_s = jnp.exp(ft + m - m_new)
    c_new = f_s * c + i_s * zt
    n_new = f_s * n + i_s
    h_new = ot * c_new / jnp.maximum(n_new, 1.0)
    return {"h": h_new, "c": c_new, "n": n_new, "m": m_new}


def apply_slstm(p, u, cfg, *, bits, qcfg: QuantConfig, state=None):
    """u: (B, T, d). Sequential lax.scan over T."""
    B, T, d = u.shape
    gx = cm.qlinear(p["wx"], u, bits=bits, qcfg=qcfg, kind="ffn")  # (B,T,4d)
    if state is None:
        state = init_slstm_state(cfg, B)

    def step(st, g_t):
        st = _slstm_cell(st, g_t, p["r"])
        return st, st["h"]

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(gx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, T, d).astype(u.dtype)
    y = cm.rmsnorm(p["norm"], y)
    return cm.qlinear(p["wo"], y, bits=bits, qcfg=qcfg, kind="ffn"), state


def decode_slstm(p, u, state, cfg, *, bits, qcfg: QuantConfig):
    out, state = apply_slstm(p, u, cfg, bits=bits, qcfg=qcfg, state=state)
    return out, state
