"""AdamW + warmup-cosine schedule + global-norm clipping, from scratch.

State layout mirrors optax: {'m': pytree, 'v': pytree, 'step': scalar}.
Moments are fp32 regardless of param dtype (bf16 params keep fp32
optimizer state -- standard mixed-precision practice on TPU).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float = 1.0
    warmup_steps: int = 150
    total_steps: int = 1000
    schedule: str = "cosine"   # 'cosine' | 'constant'


def cosine_schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    if cfg.schedule == "constant":
        return jnp.full_like(step, cfg.lr)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, cfg: OptConfig, mask=None):
    """One AdamW step. `mask` (same-structure bool pytree or None)
    freezes leaves where False (OmniQuant trains aux params only).

    Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else jnp.float32(1.0)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, keep):
        g32 = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                      + cfg.weight_decay * p.astype(jnp.float32))
        p_new = (p.astype(jnp.float32) - delta).astype(p.dtype)
        if keep is not None:
            p_new = jnp.where(keep, p_new, p)
            m_new = jnp.where(keep, m_new, m)
            v_new = jnp.where(keep, v_new, v)
        return p_new, m_new, v_new

    if mask is None:
        flat = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v, None),
                            params, grads, state["m"], state["v"])
    else:
        flat = jax.tree.map(lambda p, g, m, v, k: upd(p, g, m, v, k),
                            params, grads, state["m"], state["v"], mask)
    is_triple = lambda x: isinstance(x, tuple) and len(x) == 3 and not isinstance(x[0], tuple)
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=is_triple)
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=is_triple)
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=is_triple)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
