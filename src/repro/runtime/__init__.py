"""Distributed runtime: sharding rules, checkpointing, fault tolerance,
gradient compression."""
from repro.runtime import checkpoint, compression, fault, sharding  # noqa: F401
