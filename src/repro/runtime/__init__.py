"""Distributed runtime: sharding rules, checkpointing, fault tolerance,
gradient compression, and the serving compile-count tripwire
(compile_guard)."""
from repro.runtime import (checkpoint, compile_guard, compression,  # noqa: F401
                           fault, sharding)
