"""Checkpointing: atomic, async, keep-N, elastic reshard-on-restore.

Format: one msgpack file per checkpoint step holding every leaf as
{key-path: {dtype, shape, raw bytes}}. Writes go to `<step>.tmp/` then
an atomic rename publishes `<step>/` -- a crash mid-write can never
corrupt the latest checkpoint. An async writer thread performs the
serialization off the training thread (device->host copy happens
eagerly so training can mutate buffers immediately).

Restore is *elastic*: leaves are loaded as host numpy arrays and
device_put against whatever shardings the (possibly re-sized) relaunch
provides, so a job checkpointed on a 16x16 mesh restores cleanly onto
2x16x16 or a single host (multi-host note: on a real fleet each process
restores only its addressable shards; jax.device_put handles the
per-shard slicing from the host array).
"""

from __future__ import annotations

import os
import shutil
import threading

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(k): v for k, v in flat}, treedef


def save(path: str, step: int, tree, async_: bool = False) -> threading.Thread | None:
    """Write checkpoint for `step`. Returns the writer thread if async."""
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

    def write():
        os.makedirs(path, exist_ok=True)
        tmp = os.path.join(path, f"{step}.tmp")
        final = os.path.join(path, str(step))
        os.makedirs(tmp, exist_ok=True)
        flat, _ = _flatten(host_tree)
        payload = {
            k: {"dtype": str(v.dtype), "shape": list(v.shape),
                "data": v.tobytes()}
            for k, v in flat.items()
        }
        with open(os.path.join(tmp, "leaves.msgpack"), "wb") as f:
            f.write(msgpack.packb(payload))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(d) for d in os.listdir(path) if d.isdigit()]
    return max(steps) if steps else None


def restore(path: str, step: int, like, shardings=None):
    """Load checkpoint `step` into the structure of `like`.

    `like` may hold arrays or ShapeDtypeStructs; `shardings` (optional,
    same structure) triggers sharded device_put -- the elastic-rescale
    path. Raises KeyError on structure mismatch.
    """
    with open(os.path.join(path, str(step), "leaves.msgpack"), "rb") as f:
        payload = msgpack.unpackb(f.read())
    flat_like, treedef = _flatten(like)
    leaves = {}
    for k, spec in flat_like.items():
        if k not in payload:
            raise KeyError(f"checkpoint missing leaf {k}")
        rec = payload[k]
        arr = np.frombuffer(rec["data"], dtype=rec["dtype"]).reshape(rec["shape"])
        if tuple(arr.shape) != tuple(spec.shape):
            raise ValueError(f"shape mismatch for {k}: ckpt {arr.shape} vs {spec.shape}")
        leaves[k] = arr
    restored = jax.tree_util.tree_unflatten(
        treedef, [leaves[k] for k in flat_like.keys()]
    )
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(jnp.asarray(a), s), restored, shardings
        )
    else:
        restored = jax.tree.map(jnp.asarray, restored)
    return restored


class CheckpointManager:
    """Keep-N rotation + async-write bookkeeping."""

    def __init__(self, path: str, keep: int = 3, async_: bool = True,
                 every: int = 100):
        self.path = path
        self.keep = keep
        self.async_ = async_
        self.every = every
        self._pending: threading.Thread | None = None

    def maybe_save(self, step: int, tree, force: bool = False):
        if not force and (self.every <= 0 or step % self.every != 0):
            return False
        self.wait()
        self._pending = save(self.path, step, tree, async_=self.async_)
        self._gc(pending_step=step)
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self, pending_step: int | None = None):
        if not os.path.isdir(self.path):
            return
        on_disk = sorted(int(d) for d in os.listdir(self.path) if d.isdigit())
        steps = sorted(set(on_disk) | ({pending_step} if pending_step is not None else set()))
        drop = set(steps[: max(0, len(steps) - self.keep)])
        for s in on_disk:
            if s in drop:
                shutil.rmtree(os.path.join(self.path, str(s)), ignore_errors=True)

    def latest(self) -> int | None:
        self.wait()
        return latest_step(self.path)

    def restore(self, like, step: int | None = None, shardings=None):
        self.wait()
        step = latest_step(self.path) if step is None else step
        if step is None:
            return None
        return restore(self.path, step, like, shardings)
