"""Runtime tripwire for the one-compile-per-representation contract.

The serving stack's core compile invariant (docs/contracts.md, rule
R1's runtime twin): the scheduler's keyed closure caches compile each
decode-family closure AT MOST once per representation key -- a second
trace of `decode`/`draft`/`verify` for a key the scheduler already
visited means static metadata leaked into traced values, a donated
buffer changed layout, or host data got baked into a closure. Zero
traces is legitimate (a spec-decode scheduler builds its serving
tier's plain `decode` closure but steps through `draft`/`verify`
instead). Prefill closures legitimately retrace once per
(rows, prompt-length) bucket, so they are counted but not pinned.

`assert_no_recompiles` replaces the hand-rolled
`sched._fns[key]["decode"]._cache_size() == 1` idiom that had been
copy-pasted across test_packed_elastic / test_packed_ep /
test_paged_kv / test_specdecode, and `compile_counts` feeds the
per-benchmark `compile_counts` baseline in BENCH_serve.json so a
compile-count regression shows up in review as a JSON diff.
"""

from __future__ import annotations

__all__ = ["RecompileError", "jit_cache_size", "compile_counts",
           "assert_no_recompiles", "EXACT_ONCE"]

# closures that must compile at most once per representation key; the
# prefill family retraces per prompt-shape bucket by design
EXACT_ONCE = ("decode", "draft", "verify")


class RecompileError(AssertionError):
    """A decode-family closure traced more than once for one key."""


def jit_cache_size(fn) -> int:
    """Number of traces a jitted callable has accumulated.

    jax 0.4.x exposes this as `PjitFunction._cache_size()`; failing
    loudly on drift beats silently guarding nothing.
    """
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        raise RuntimeError(
            f"{fn!r} exposes no _cache_size(); jax version drift -- "
            f"update repro.runtime.compile_guard.jit_cache_size")
    return int(probe())


def compile_counts(sched) -> dict:
    """Per-key trace counts of a scheduler's compiled-closure cache.

    Returns `{"per_key": {repr(key): {closure: traces}}, "total": n}`
    -- JSON-ready (keys stringified via repr, so tuple keys like
    `(2, 'ep')` or `('spec', ('slice', 2), 8)` survive serialization).
    """
    per_key = {
        repr(key): {name: jit_cache_size(fn) for name, fn in fns.items()}
        for key, fns in sched._fns.items()
    }
    total = sum(n for fns in per_key.values() for n in fns.values())
    return {"per_key": per_key, "total": total}


def assert_no_recompiles(sched, *, expect_keys=None, require_keys=None):
    """Assert no decode-family closure compiled more than once per key.

    expect_keys: exact set the closure cache must equal (catches both
        missing representations and stray extra compiles for keys that
        should never have been visited).
    require_keys: subset the cache must at least contain (for paths
        that legitimately build additional keys, e.g. spec-decode
        schedulers that also keep their serving tier's closures).

    Returns `compile_counts(sched)` so callers can log or persist the
    verified baseline in the same breath.
    """
    have = set(sched._fns)
    if expect_keys is not None and have != set(expect_keys):
        raise RecompileError(
            f"closure-cache keys {sorted(map(repr, have))} != expected "
            f"{sorted(map(repr, set(expect_keys)))}")
    if require_keys is not None and not set(require_keys) <= have:
        missing = set(require_keys) - have
        raise RecompileError(
            f"closure cache missing required keys "
            f"{sorted(map(repr, missing))} (have {sorted(map(repr, have))})")
    offenders = []
    for key, fns in sched._fns.items():
        for name in EXACT_ONCE:
            fn = fns.get(name)
            if fn is None:
                continue
            n = jit_cache_size(fn)
            if n > 1:
                offenders.append(f"{key!r}:{name} traced {n}x "
                                 f"(revisits must be cache hits)")
    if offenders:
        raise RecompileError(
            "one-compile-per-key contract violated: "
            + "; ".join(offenders))
    return compile_counts(sched)
