"""Gradient compression for cross-pod all-reduce (EF int8 psum).

Inter-pod links are the slowest hop in a multi-pod job (data-center
network vs intra-pod ICI), so only the 'pod'-axis reduction is
compressed: gradients are quantized to int8 with a per-tensor-block
scale, psum'd over 'pod', and dequantized; the quantization residual is
carried in an error-feedback buffer (EF21-style) so compression bias
vanishes over steps instead of accumulating.

Two entry points:
  * `compress_decompress(tree, ef, bits)` -- pure, psum-free; models
    the wire format and the EF recursion (unit-testable anywhere).
  * `compressed_psum_tree(tree, ef, axis, bits)` -- the real collective,
    for use inside shard_map over the 'pod' mesh axis. Cross-pod bytes
    drop 2x (bf16->int8) or 4x (int4); the dry-run HLO shows the
    all-reduce operand dtype change.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_BLOCK = 2048  # quantization group size along the flattened tensor


def _quantize_leaf(g: jax.Array, bits: int):
    """Symmetric per-block quantization of one gradient tensor."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % _BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, _BLOCK)
    maxv = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.where(maxv > 0, maxv / qmax, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -qmax - 1, qmax).astype(jnp.int8)
    return q, scale


def _dequantize_leaf(q: jax.Array, scale: jax.Array, shape, dtype):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compress_decompress(tree, ef, bits: int = 8):
    """Quantize+dequantize each leaf with error feedback.

    Returns (decompressed tree, new ef). ef=None initializes zeros.
    """
    if ef is None:
        ef = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), tree)

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = _quantize_leaf(target, bits)
        deq = _dequantize_leaf(q, scale, g.shape, jnp.float32)
        return deq.astype(g.dtype), target - deq

    pairs = jax.tree.map(one, tree, ef)
    out = jax.tree.map(lambda t: t[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
    return out, new_ef


def compressed_psum_tree(tree, ef, axis: str, bits: int = 8):
    """EF-compressed mean-psum over `axis` (call inside shard_map).

    Scheme (exact given the shared scale):
      1. per-block max |g|, pmax'd over the axis (tiny collective) so
         every pod quantizes on the SAME grid;
      2. int8 codes psum'd at int32 accumulation -- this is the only
         full-size tensor crossing the slow link (2x fewer bytes than
         bf16, 4x fewer than fp32);
      3. dequantize the summed codes, divide by pod count;
      4. residual (target - local dequant) feeds the next step's EF.
    """
    n = jax.lax.psum(1, axis)
    if ef is None:
        ef = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), tree)
    qmax = 2 ** (bits - 1) - 1

    def one(g, e):
        target = g.astype(jnp.float32) + e
        flat = target.reshape(-1)
        pad = (-flat.size) % _BLOCK
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
        blocks = flat.reshape(-1, _BLOCK)
        maxv = jax.lax.pmax(jnp.max(jnp.abs(blocks), axis=1, keepdims=True), axis)
        scale = jnp.where(maxv > 0, maxv / qmax, 1.0)
        q = jnp.clip(jnp.round(blocks / scale), -qmax - 1, qmax).astype(jnp.int8)
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis)
        out = _dequantize_leaf(q_sum, scale, g.shape, jnp.float32) / n
        local = _dequantize_leaf(q, scale, g.shape, jnp.float32)
        return out.astype(g.dtype), target - local

    pairs = jax.tree.map(one, tree, ef)
    out = jax.tree.map(lambda t: t[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
    return out, new_ef
