"""Fault tolerance: straggler monitor, heartbeat, resilient step loop.

At 1000+ node scale the assumptions are: (a) some host WILL crash
mid-run, (b) some step WILL stall (network flap, preemption warning,
slow HBM ECC retry), (c) the scheduler may relaunch the job on a
different topology. The pieces here cover all three:

* `StepMonitor` -- EMA step timer; flags steps slower than k x EMA and
  invokes a pluggable callback (on a fleet: report to the scheduler /
  trigger within-job rebalance; serve/fleet.py wires it as a replica
  health signal).
* `Heartbeat` -- step/timestamp file an external watchdog can poll to
  detect a hung process and SIGKILL->relaunch it. `stale()` is that
  watchdog check: serve/fleet.py polls it per fleet step to decide
  when a replica (in-process or subprocess) stopped making progress
  and must be drained.
* `run_resilient` -- wraps a step function with crash-restore-retry
  against a CheckpointManager; elastic restore happens naturally since
  restore() reshards onto whatever mesh the relaunch built.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    ema: float


class StepMonitor:
    def __init__(self, threshold: float = 2.5, decay: float = 0.9,
                 warmup_steps: int = 3, on_straggler: Callable | None = None):
        self.threshold = threshold
        self.decay = decay
        self.warmup_steps = warmup_steps
        self.on_straggler = on_straggler
        self.ema: float | None = None
        self.events: list[StragglerEvent] = []
        self._seen = 0

    def record(self, step: int, step_time: float) -> bool:
        """Feed one step's wall time; returns True if flagged straggler."""
        self._seen += 1
        flagged = False
        # `self.ema > 0` guards the degenerate baseline: under a virtual
        # clock (or a first step faster than the timer resolution) the
        # EMA seeds at 0.0 and EVERY later step would flag -- a zero
        # baseline carries no straggler information.
        if (self.ema is not None and self.ema > 0
                and self._seen > self.warmup_steps):
            if step_time > self.threshold * self.ema:
                ev = StragglerEvent(step, step_time, self.ema)
                self.events.append(ev)
                if self.on_straggler:
                    self.on_straggler(ev)
                flagged = True
        if self.ema is None:
            self.ema = step_time
        elif not flagged:  # stragglers don't poison the EMA
            self.ema = self.decay * self.ema + (1 - self.decay) * step_time
        return flagged


class Heartbeat:
    """Atomic step/timestamp file plus the watchdog-side staleness check.

    `clock` is injectable (tests drive a virtual clock); it must be the
    SAME time base on the beating and the watching side -- the fleet
    passes one clock to both.
    """

    def __init__(self, path: str, clock: Callable[[], float] = time.time):
        self.path = path
        self.clock = clock

    def beat(self, step: int):
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": self.clock()}, f)
        os.replace(tmp, self.path)

    def read(self):
        """Last beat dict, or None if absent/unreadable. A torn or
        truncated file (the writer was SIGKILLed; an external tool
        clobbered it) reads as None rather than raising -- to a
        watchdog an unreadable heartbeat IS a missing heartbeat."""
        try:
            with open(self.path) as f:
                return json.load(f)
        except (OSError, ValueError):
            return None

    def stale(self, timeout: float, now: float | None = None) -> bool:
        """True when the last beat is older than `timeout` seconds (or
        was never written / cannot be read): the process behind this
        file has stopped making progress and should be treated as dead.
        """
        last = self.read()
        if last is None or "time" not in last:
            return True
        now = self.clock() if now is None else now
        return (now - float(last["time"])) > timeout


def run_resilient(
    *,
    num_steps: int,
    make_state: Callable[[], dict],
    step_fn: Callable[[dict, int], dict],
    ckpt,                      # CheckpointManager
    max_restarts: int = 3,
    monitor: StepMonitor | None = None,
    heartbeat: Heartbeat | None = None,
    recoverable=(RuntimeError,),
):
    """Run `step_fn` for num_steps with checkpoint/restart semantics.

    `make_state()` builds fresh state; if a checkpoint exists the loop
    resumes from it (restart == relaunch). `step_fn(state, step)` must
    be deterministic given (state, step) -- data comes from the
    deterministic host-sharded pipeline keyed by step, so a resumed run
    is bitwise identical to an uninterrupted one (tested).
    """
    restarts = 0
    while True:
        state = make_state()
        start = 0
        latest = ckpt.latest()
        if latest is not None:
            state = ckpt.restore(state, step=latest)
            start = latest + 1
        try:
            for step in range(start, num_steps):
                t0 = time.perf_counter()
                state = step_fn(state, step)
                if monitor is not None:
                    monitor.record(step, time.perf_counter() - t0)
                if heartbeat is not None:
                    heartbeat.beat(step)
                ckpt.maybe_save(step, state)
            ckpt.maybe_save(num_steps - 1, state, force=True)
            ckpt.wait()
            return state, restarts
        except recoverable:
            restarts += 1
            if restarts > max_restarts:
                raise
            # fall through: rebuild state, restore from latest checkpoint
