"""Fault tolerance: straggler monitor, heartbeat, resilient step loop.

At 1000+ node scale the assumptions are: (a) some host WILL crash
mid-run, (b) some step WILL stall (network flap, preemption warning,
slow HBM ECC retry), (c) the scheduler may relaunch the job on a
different topology. The pieces here cover all three:

* `StepMonitor` -- EMA step timer; flags steps slower than k x EMA and
  invokes a pluggable callback (on a fleet: report to the scheduler /
  trigger within-job rebalance; here: log + count, unit-tested).
* `Heartbeat` -- step/timestamp file an external watchdog can poll to
  detect a hung process and SIGKILL->relaunch it.
* `run_resilient` -- wraps a step function with crash-restore-retry
  against a CheckpointManager; elastic restore happens naturally since
  restore() reshards onto whatever mesh the relaunch built.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    ema: float


class StepMonitor:
    def __init__(self, threshold: float = 2.5, decay: float = 0.9,
                 warmup_steps: int = 3, on_straggler: Callable | None = None):
        self.threshold = threshold
        self.decay = decay
        self.warmup_steps = warmup_steps
        self.on_straggler = on_straggler
        self.ema: float | None = None
        self.events: list[StragglerEvent] = []
        self._seen = 0

    def record(self, step: int, step_time: float) -> bool:
        """Feed one step's wall time; returns True if flagged straggler."""
        self._seen += 1
        flagged = False
        if self.ema is not None and self._seen > self.warmup_steps:
            if step_time > self.threshold * self.ema:
                ev = StragglerEvent(step, step_time, self.ema)
                self.events.append(ev)
                if self.on_straggler:
                    self.on_straggler(ev)
                flagged = True
        if self.ema is None:
            self.ema = step_time
        elif not flagged:  # stragglers don't poison the EMA
            self.ema = self.decay * self.ema + (1 - self.decay) * step_time
        return flagged


class Heartbeat:
    def __init__(self, path: str):
        self.path = path

    def beat(self, step: int):
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": time.time()}, f)
        os.replace(tmp, self.path)

    def read(self):
        if not os.path.exists(self.path):
            return None
        with open(self.path) as f:
            return json.load(f)


def run_resilient(
    *,
    num_steps: int,
    make_state: Callable[[], dict],
    step_fn: Callable[[dict, int], dict],
    ckpt,                      # CheckpointManager
    max_restarts: int = 3,
    monitor: StepMonitor | None = None,
    heartbeat: Heartbeat | None = None,
    recoverable=(RuntimeError,),
):
    """Run `step_fn` for num_steps with checkpoint/restart semantics.

    `make_state()` builds fresh state; if a checkpoint exists the loop
    resumes from it (restart == relaunch). `step_fn(state, step)` must
    be deterministic given (state, step) -- data comes from the
    deterministic host-sharded pipeline keyed by step, so a resumed run
    is bitwise identical to an uninterrupted one (tested).
    """
    restarts = 0
    while True:
        state = make_state()
        start = 0
        latest = ckpt.latest()
        if latest is not None:
            state = ckpt.restore(state, step=latest)
            start = latest + 1
        try:
            for step in range(start, num_steps):
                t0 = time.perf_counter()
                state = step_fn(state, step)
                if monitor is not None:
                    monitor.record(step, time.perf_counter() - t0)
                if heartbeat is not None:
                    heartbeat.beat(step)
                ckpt.maybe_save(step, state)
            ckpt.maybe_save(num_steps - 1, state, force=True)
            ckpt.wait()
            return state, restarts
        except recoverable:
            restarts += 1
            if restarts > max_restarts:
                raise
            # fall through: rebuild state, restore from latest checkpoint
