"""Logical-axis sharding rules (MaxText-style) -> NamedShardings.

Every param/activation/cache dimension carries a *logical* name; RULES
lists candidate mesh axes per name. The resolver picks the first
candidate whose axes (a) exist in the mesh, (b) divide the dim size,
and (c) are not already used by another dim of the same array. This
makes one rule table serve every architecture: e.g. 'experts' shards
over 'model' for 32-expert MoE but falls through (leaving 'expert_mlp'
to take 'model') for the non-divisible 40-expert config.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# Version-compat shims. JAX >= 0.5 grew `jax.sharding.AxisType` (explicit
# sharding meshes) and promoted `shard_map` out of jax.experimental; 0.4.x
# (this container ships 0.4.37) has neither. All mesh construction and
# shard_map use in this repo goes through these two names so the code runs
# on both sides of the API change.
# ---------------------------------------------------------------------------


def make_mesh(axis_shapes, axis_names, *, axis_types=None):
    """`jax.make_mesh` with version-tolerant `axis_types`.

    On JAX >= 0.5 the mesh is built with explicit axis types (defaulting
    every axis to `AxisType.Auto`, the GSPMD-propagated behavior this
    repo relies on). On 0.4.x, where `jax.sharding.AxisType` does not
    exist and meshes are always auto-sharded, the kwarg is omitted.
    """
    axis_type_cls = getattr(jax.sharding, "AxisType", None)
    if axis_type_cls is None:
        return jax.make_mesh(axis_shapes, axis_names)
    if axis_types is None:
        axis_types = (axis_type_cls.Auto,) * len(axis_names)
    return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types)


if hasattr(jax, "shard_map"):           # JAX >= 0.5
    shard_map = jax.shard_map
else:                                    # 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401

# logical name -> ordered candidates; each candidate is a tuple of mesh
# axes (a multi-axis candidate shards one dim over several mesh axes).
RULES: dict[str, list[tuple[str, ...]]] = {
    # weights
    "embed": [("data",)],                 # FSDP over the data axis
    "vocab": [("model",)],
    "q_heads": [("model",)],
    "kv_heads": [("model",)],
    "mlp": [("model",)],
    "inner": [("model",)],
    "experts": [("model",)],
    "expert_mlp": [("model",)],
    "layer": [],
    # activations
    "batch": [("pod", "data"), ("data",)],
    "seq": [],
    "vocab_act": [("model",)],
    # decode caches: sequence-sharded over 'model' -- GSPMD lowers the
    # softmax over the sharded seq dim into tiny stat psums + a small
    # psum of the output (flash-decoding pattern) instead of gathering
    # the cache (measured: 2x1GB/layer all-gathers with head sharding).
    "kv_seq": [("model",)],
    "kv_heads_cache": [("model",)],
    "head_dim_cache": [("model",)],
    "heads_cache": [("model",)],
}


def serving_rules() -> dict:
    """Rules for serve cells: TP-only weights (no FSDP 'data' sharding).
    At decode, FSDP would all-gather every weight every step; serving
    keeps weights resident sharded over 'model' and uses 'data' purely
    for request batch parallelism."""
    rules = dict(RULES)
    rules["embed"] = []
    return rules

ACT_RULES = {
    "batch": RULES["batch"],
    "seq": [],
    "embed": [],
    "vocab": [("model",)],
    # MoE dispatch buffers: experts over 'model' (EP); the capacity dim
    # takes whatever is left (40-expert configs fall through to it).
    "tokens": [("pod", "data"), ("data",)],
    "experts": [("model",)],
    # capacity prefers 'data': the expert einsum contracts d and shards
    # its OUTPUT f over 'model', so capacity@model would collide.
    "moe_capacity": [("pod", "data"), ("data",)],
}


def mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve_spec(logical_axes, shape, mesh: Mesh, rules=None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec for `shape`."""
    rules = rules or RULES
    sizes = mesh_axis_sizes(mesh)
    used: set[str] = set()
    out = []
    if logical_axes is None:
        logical_axes = (None,) * len(shape)
    # pad/trim to rank
    logical_axes = tuple(logical_axes) + (None,) * (len(shape) - len(logical_axes))
    for dim, name in zip(shape, logical_axes[: len(shape)]):
        chosen = None
        for cand in rules.get(name, []) if name else []:
            axes = tuple(a for a in cand if a in sizes)
            if not axes or any(a in used for a in axes):
                continue
            total = int(np.prod([sizes[a] for a in axes]))
            if dim % total == 0:
                chosen = axes if len(axes) > 1 else axes[0]
                used.update(axes)
                break
        out.append(chosen)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_shardings(axes_tree, shape_tree, mesh: Mesh, rules=None):
    """NamedSharding pytree from (logical-axes pytree, ShapeDtype pytree)."""
    is_axes_leaf = lambda x: x is None or (
        isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)
    )
    flat_axes = jax.tree.flatten(axes_tree, is_leaf=is_axes_leaf)[0]
    flat_shapes, treedef = jax.tree.flatten(shape_tree)
    assert len(flat_axes) == len(flat_shapes), (
        f"axes/shape tree mismatch: {len(flat_axes)} vs {len(flat_shapes)}"
    )
    shardings = [
        NamedSharding(mesh, resolve_spec(a, s.shape, mesh, rules))
        for a, s in zip(flat_axes, flat_shapes)
    ]
    return jax.tree.unflatten(treedef, shardings)


def make_act_resolver(mesh: Mesh):
    """Resolver consumed by repro.models.common.constrain."""

    def resolver(logical_axes_and_shape):
        logical_axes, shape = logical_axes_and_shape
        return NamedSharding(mesh, resolve_spec(logical_axes, shape, mesh, ACT_RULES))

    return resolver


BATCH_INPUT_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "frames": ("batch", None, None),
    "vision_embeds": ("batch", None, None),
    "positions": ("batch", "seq", None),
    "token": ("batch", None),
    "pos": (),
}


def batch_shardings(batch_specs, mesh: Mesh):
    return {
        k: NamedSharding(mesh, resolve_spec(BATCH_INPUT_AXES.get(k), v.shape, mesh))
        for k, v in batch_specs.items()
    }
