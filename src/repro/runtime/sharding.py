"""Logical-axis sharding rules (MaxText-style) -> NamedShardings.

Every param/activation/cache dimension carries a *logical* name; RULES
lists candidate mesh axes per name. The resolver picks the first
candidate whose axes (a) exist in the mesh, (b) divide the dim size,
and (c) are not already used by another dim of the same array. This
makes one rule table serve every architecture: e.g. 'experts' shards
over 'model' for 32-expert MoE but falls through (leaving 'expert_mlp'
to take 'model') for the non-divisible 40-expert config.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# Version-compat shims. JAX >= 0.5 grew `jax.sharding.AxisType` (explicit
# sharding meshes) and promoted `shard_map` out of jax.experimental; 0.4.x
# (this container ships 0.4.37) has neither. All mesh construction and
# shard_map use in this repo goes through these two names so the code runs
# on both sides of the API change.
# ---------------------------------------------------------------------------


def make_mesh(axis_shapes, axis_names, *, axis_types=None):
    """`jax.make_mesh` with version-tolerant `axis_types`.

    On JAX >= 0.5 the mesh is built with explicit axis types (defaulting
    every axis to `AxisType.Auto`, the GSPMD-propagated behavior this
    repo relies on). On 0.4.x, where `jax.sharding.AxisType` does not
    exist and meshes are always auto-sharded, the kwarg is omitted.
    """
    axis_type_cls = getattr(jax.sharding, "AxisType", None)
    if axis_type_cls is None:
        return jax.make_mesh(axis_shapes, axis_names)
    if axis_types is None:
        axis_types = (axis_type_cls.Auto,) * len(axis_names)
    return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types)


if hasattr(jax, "shard_map"):           # JAX >= 0.5
    shard_map = jax.shard_map
else:                                    # 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401

# logical name -> ordered candidates; each candidate is a tuple of mesh
# axes (a multi-axis candidate shards one dim over several mesh axes).
RULES: dict[str, list[tuple[str, ...]]] = {
    # weights
    "embed": [("data",)],                 # FSDP over the data axis
    "vocab": [("model",)],
    "q_heads": [("model",)],
    "kv_heads": [("model",)],
    "mlp": [("model",)],
    "inner": [("model",)],
    "experts": [("model",)],
    "expert_mlp": [("model",)],
    "layer": [],
    # activations
    "batch": [("pod", "data"), ("data",)],
    "seq": [],
    "vocab_act": [("model",)],
    # decode caches: sequence-sharded over 'model' -- GSPMD lowers the
    # softmax over the sharded seq dim into tiny stat psums + a small
    # psum of the output (flash-decoding pattern) instead of gathering
    # the cache (measured: 2x1GB/layer all-gathers with head sharding).
    "kv_seq": [("model",)],
    "kv_heads_cache": [("model",)],
    "head_dim_cache": [("model",)],
    "heads_cache": [("model",)],
}


def serving_rules() -> dict:
    """Rules for serve cells: TP-only weights (no FSDP 'data' sharding).
    At decode, FSDP would all-gather every weight every step; serving
    keeps weights resident sharded over 'model' and uses 'data' purely
    for request batch parallelism."""
    rules = dict(RULES)
    rules["embed"] = []
    return rules


# Slot-array decode state on a serving mesh: the slot (batch) axis
# shards over 'data' (request parallelism) and the KV heads over
# 'model' (each TP shard attends over its own heads; a kv-head count
# that does not divide the model axis leaves the cache replicated).
# The sequence axis stays UNSHARDED -- the continuous-batching decode
# writes each slot's new k/v at a *traced* per-slot position
# (dynamic_update_slice at pos[slot]), which on a seq-sharded buffer
# would force GSPMD into cross-shard masked updates every step;
# head-sharding keeps every write local to one shard. (Training/dryrun
# cells keep the flash-decoding kv_seq@model rule in RULES above.)
SERVE_STATE_RULES: dict[str, list[tuple[str, ...]]] = {
    "layer": [],
    "batch": [("data",)],
    "kv_seq": [],
    "kv_heads_cache": [("model",)],
    # head_dim deliberately has NO rule here: when the kv-head count
    # does not divide the model axis the cache stays head-replicated
    # rather than splitting inside a head (sub-head shards force XLA
    # into layout-thrashing full rematerializations around the GQA
    # reshapes -- and the projections are head-granular too, see
    # `tree_shardings(units=)`).
    "head_dim_cache": [],
    "heads_cache": [("model",)],
    # paged KV store: the page dims stay UNSHARDED -- pages are
    # addressed by a host-side page table whose ids must resolve on
    # every shard, so only the per-head dim splits over 'model'
    # (kv_heads_cache above); the global page pool is the paged twin of
    # the batch axis and 'data' request-parallelism instead rides the
    # page-table rows.
    "page": [],
    "page_row": [],
}

ACT_RULES = {
    "batch": RULES["batch"],
    "seq": [],
    "embed": [],
    "vocab": [("model",)],
    # MoE dispatch buffers: experts over 'model' (EP); the capacity dim
    # takes whatever is left (40-expert configs fall through to it).
    "tokens": [("pod", "data"), ("data",)],
    "experts": [("model",)],
    # capacity prefers 'data': the expert einsum contracts d and shards
    # its OUTPUT f over 'model', so capacity@model would collide.
    "moe_capacity": [("pod", "data"), ("data",)],
}


def mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve_spec(logical_axes, shape, mesh: Mesh, rules=None,
                 units=None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec for `shape`.

    `units` (logical name -> element-group size) constrains a dim to
    shard only at whole-group boundaries: a candidate is taken only if
    the number of GROUPS divides the mesh axes. The serving resolver
    passes {'q_heads': head_dim, 'kv_heads': head_dim} so attention
    projections shard head-granularly (sub-head column shards are never
    a sane TP layout -- every downstream (heads, head_dim) reshape
    would cross shard boundaries); a dim that cannot shard at its
    granularity falls through to replicated.
    """
    rules = rules or RULES
    units = units or {}
    sizes = mesh_axis_sizes(mesh)
    used: set[str] = set()
    out = []
    if logical_axes is None:
        logical_axes = (None,) * len(shape)
    # pad/trim to rank
    logical_axes = tuple(logical_axes) + (None,) * (len(shape) - len(logical_axes))
    for dim, name in zip(shape, logical_axes[: len(shape)]):
        chosen = None
        unit = units.get(name, 1)
        groups = dim // unit if unit and dim % unit == 0 else 0
        for cand in rules.get(name, []) if name else []:
            axes = tuple(a for a in cand if a in sizes)
            if not axes or any(a in used for a in axes):
                continue
            total = int(np.prod([sizes[a] for a in axes]))
            if groups and groups % total == 0:
                chosen = axes if len(axes) > 1 else axes[0]
                used.update(axes)
                break
        out.append(chosen)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_shardings(axes_tree, shape_tree, mesh: Mesh, rules=None, units=None):
    """NamedSharding pytree from (logical-axes pytree, ShapeDtype pytree).

    The axes tree is flattened *up to* the shape tree's treedef, so the
    two stay aligned even when the axes tree carries structure the
    shape tree drops -- e.g. a `PackedPlane` of axes tuples whose
    `overflow` spec is None while the plane's overflow leaf is absent
    (the non-extra-precision packed serving layout).
    """
    flat_shapes, treedef = jax.tree.flatten(shape_tree)
    # flatten_up_to raises on any axes/shape structure mismatch
    flat_axes = treedef.flatten_up_to(axes_tree)
    shardings = [
        NamedSharding(mesh, resolve_spec(a, s.shape, mesh, rules, units))
        for a, s in zip(flat_axes, flat_shapes)
    ]
    return jax.tree.unflatten(treedef, shardings)


def make_act_resolver(mesh: Mesh):
    """Resolver consumed by repro.models.common.constrain."""

    def resolver(logical_axes_and_shape):
        logical_axes, shape = logical_axes_and_shape
        return NamedSharding(mesh, resolve_spec(logical_axes, shape, mesh, ACT_RULES))

    return resolver


BATCH_INPUT_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "frames": ("batch", None, None),
    "vision_embeds": ("batch", None, None),
    "positions": ("batch", "seq", None),
    "token": ("batch", None),
    "pos": (),
}


def batch_shardings(batch_specs, mesh: Mesh):
    return {
        k: NamedSharding(mesh, resolve_spec(BATCH_INPUT_AXES.get(k), v.shape, mesh))
        for k, v in batch_specs.items()
    }
