from repro.serve.engine import (Engine, ServeConfig,  # noqa: F401
                                build_packed_parent,
                                materialize_packed_params,
                                materialize_served_params,
                                served_effective_bits,
                                served_nbytes,
                                served_param_shardings,
                                served_plane_nbytes_per_device,
                                served_weight_nbytes)
from repro.serve.kv_cache import (KVCacheConfig, PagedPool,  # noqa: F401
                                  PagePool)
from repro.serve.metrics import ServeMetrics  # noqa: F401
from repro.serve.router import (ElasticPrecisionRouter, PrecisionTier,  # noqa: F401
                                TierCache, TierEntry, default_tiers)
from repro.serve.scheduler import (ContinuousBatchingScheduler,  # noqa: F401
                                   Request)
from repro.serve.specdecode import (SpecDecodeConfig,  # noqa: F401
                                    accept_lengths, draft_params_for,
                                    extra_plane_nbytes)
