from repro.serve.engine import (Engine, ServeConfig,  # noqa: F401
                                build_packed_parent,
                                materialize_packed_params,
                                materialize_served_params,
                                served_effective_bits,
                                served_nbytes,
                                served_param_shardings,
                                served_plane_nbytes_per_device,
                                served_weight_nbytes)
from repro.serve.fleet import (Fleet, Replica,  # noqa: F401
                               SubprocessReplica, build_fleet)
from repro.serve.kv_cache import (KVCacheConfig, PagedPool,  # noqa: F401
                                  PagePool)
from repro.serve.metrics import FleetMetrics, ServeMetrics  # noqa: F401
from repro.serve.router import (ElasticPrecisionRouter, FleetRouter,  # noqa: F401
                                PrecisionTier, TierCache, TierEntry,
                                default_tiers)
from repro.serve.scheduler import (ContinuousBatchingScheduler,  # noqa: F401
                                   Request)
from repro.serve.specdecode import (SpecDecodeConfig,  # noqa: F401
                                    accept_lengths, draft_params_for,
                                    extra_plane_nbytes)
