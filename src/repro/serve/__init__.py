from repro.serve.engine import Engine, ServeConfig, materialize_served_params  # noqa: F401
