"""Serving engine: packed weights, Mix'n'Match, batched generation.

Deployment flow (paper Section 5.4): one int8 *parent* checkpoint is
stored; at load time each layer's weights are sliced to the precision
the deployment demands (uniform int8/6/4/3/2 or a per-layer
Mix'n'Match vector), packed, and served. Execution paths:

  * TPU: the Pallas `quant_matmul` kernel consumes packed planes and
    dequantizes in VMEM (kernels/quant_matmul.py).
  * CPU/tests: weights are materialized as their dequantized values
    (`materialize_served_params`) -- numerically IDENTICAL to the
    packed path (test_serve proves it equals fake-quant forward).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.models import api

# projection names whose 'w' leaf is a quantized (ffn-scope) weight
_FFN_PROJ = {"up", "gate", "down", "wz", "wx", "wo", "wq", "wk", "wv"}
_ATTN_PARENT = {"attn", "self_attn", "cross_attn"}
_FFN_PARENT = {"ffn", "moe", "mamba", "mlstm", "slstm"}


def _path_names(path) -> list[str]:
    out = []
    for k in path:
        name = getattr(k, "key", None)
        if name is None:
            name = str(getattr(k, "idx", k))
        out.append(str(name))
    return out


def quantized_leaf_kind(path) -> str | None:
    """'ffn' / 'attn' if this param path is a quantizable weight."""
    names = _path_names(path)
    if not names or names[-1] != "w":
        return None
    parents = set(names[:-1])
    proj = names[-2] if len(names) >= 2 else ""
    if parents & _ATTN_PARENT and proj in {"wq", "wk", "wv", "wo"}:
        return "attn"
    if parents & _FFN_PARENT and proj in _FFN_PROJ:
        if proj in {"wq", "wk", "wv"} and "mlstm" not in parents:
            return "attn"
        return "ffn"
    return None


def materialize_served_params(params, cfg, bits, extra_precision: bool | None = None):
    """Replace quantized weights with their sliced-dequantized values.

    bits: int (uniform) or per-layer list/array (Mix'n'Match; applied to
    leaves whose leading axis is the stacked layer dim)."""
    qcfg = cfg.quant
    ep = qcfg.extra_precision if extra_precision is None else extra_precision
    per_layer = not isinstance(bits, int)
    if per_layer:
        bits_arr = jnp.asarray(bits, jnp.int32)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        kind = quantized_leaf_kind(path)
        scoped = kind == "ffn" or (kind == "attn" and "attn" in qcfg.scope)
        if not scoped:
            out.append(leaf)
            continue
        names = _path_names(path)
        stacked = names[0] in ("layers", "encoder", "decoder") and leaf.ndim >= 3
        moe = "moe" in names
        # minmax group = the reduction dim: (L, E, d_in, d_out) -> 2,
        # (L, d_in, d_out) -> 1, (E, d_in, d_out) -> 1, (d_in, d_out) -> 0
        if stacked:
            group_axis = 2 if (moe and leaf.ndim == 4) else 1
        else:
            group_axis = 1 if (moe and leaf.ndim == 3) else 0
        if per_layer and stacked:
            qd = jax.vmap(
                lambda w, b: quant.quant_dequant(
                    w, qcfg.parent_bits, b, axis=group_axis - 1,
                    extra_precision=ep)
            )(leaf, bits_arr[: leaf.shape[0]])
        else:
            b = int(bits) if not per_layer else int(bits[0])
            qd = quant.quant_dequant(leaf, qcfg.parent_bits, b, axis=group_axis,
                                     extra_precision=ep)
        out.append(qd.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def materialize_packed_params(params, cfg, bits: int):
    """Replace quantized weights with PACKED r-bit planes.

    Each scoped 'w' leaf becomes {'words': int32 packed codes (along the
    reduction dim), 'alpha', 'beta'}: w_hat = alpha * code - beta. The
    int8 parent is quantized per-output-channel, sliced to `bits`, and
    packed -- HBM weight bytes drop 16/bits x vs bf16. Consumed by
    common.qlinear (jnp path) or kernels.quant_matmul (TPU).
    Dense/VLM/encdec projections only (MoE expert stacks keep the
    fake-quant path; their dispatch dominates serving cost anyway).
    """
    qcfg = cfg.quant
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        kind = quantized_leaf_kind(path)
        scoped = kind == "ffn" or (kind == "attn" and "attn" in qcfg.scope)
        names = _path_names(path)
        if not scoped or "moe" in names or leaf.ndim > 3:
            out.append(leaf)
            continue
        w32 = leaf.astype(jnp.float32)
        q, alpha, z = quant.quantize(w32, qcfg.parent_bits, axis=-2)
        codes = quant.sliced_codes(q, qcfg.parent_bits, bits)
        scale = jnp.asarray(2 ** (qcfg.parent_bits - bits), jnp.float32)
        from repro.core import packing
        # down-type projections (out dim = residual 'embed') pack along N
        # so the packed plane stays sharded on its reduction dim under
        # TP; everything else packs along K and shards the out dim.
        proj = names[-2] if len(names) >= 2 else ""
        pack_axis = -1 if proj in ("down", "wo") else -2
        out.append({
            "words": packing.pack_codes(codes, bits, axis=pack_axis),
            "alpha": alpha * scale,
            "beta": alpha * z,
        })

    # rebuild by mutating a container-copied tree by key-path (leaf
    # structure changes, so tree_unflatten can't be used directly)
    def set_path(d, path, value):
        node = d
        for k in path[:-1]:
            node = node[getattr(k, "key", getattr(k, "idx", None))]
        node[getattr(path[-1], "key", getattr(path[-1], "idx", None))] = value

    base = _deep_copy_containers(params)
    for (path, _), new_leaf in zip(flat, out):
        set_path(base, path, new_leaf)
    return base


def _deep_copy_containers(tree):
    if isinstance(tree, dict):
        return {k: _deep_copy_containers(v) for k, v in tree.items()}
    if isinstance(tree, list):
        return [_deep_copy_containers(v) for v in tree]
    if isinstance(tree, tuple):
        return tuple(_deep_copy_containers(v) for v in tree)
    return tree


def packed_axes(axes_tree, params_packed, cfg):
    """Logical-axes tree matching `materialize_packed_params` output:
    wherever the packed params carry {'words','alpha','beta'}, the axes
    leaf {'w': (..., a_in, a_out)} becomes the packed trio sharded on
    a_out (the packed reduction dim stays unsharded)."""

    def walk(ax_node, p_node, path):
        if isinstance(p_node, dict) and "words" in p_node:
            # ax_node is the original 'w' spec tuple (..., a_in, a_out)
            spec = tuple(ax_node)
            rest, a_in, a_out = spec[:-2], spec[-2], spec[-1]
            # path ends with the 'w' key; the projection name precedes it
            proj = path[-2] if len(path) >= 2 else ""
            if proj in ("down", "wo"):        # packed along N: keep K shard
                words = rest + (a_in, None)
            else:                             # packed along K: keep N shard
                words = rest + (None, a_out)
            scales = rest + (None, a_out)
            return {"words": words, "alpha": scales, "beta": scales}
        if isinstance(p_node, dict):
            return {k: walk(ax_node[k], p_node[k], path + [k]) for k in p_node}
        if isinstance(p_node, list):
            return [walk(a, v, path + [i])
                    for i, (a, v) in enumerate(zip(ax_node, p_node))]
        return ax_node

    return walk(axes_tree, params_packed, [])


@dataclasses.dataclass
class ServeConfig:
    bits: object = 8                 # int or per-layer list (Mix'n'Match)
    max_len: int = 512
    extra_precision: bool = False
    use_packed: bool = False         # TPU kernel path


class Engine:
    """Batched greedy-decoding engine over materialized served weights."""

    def __init__(self, params, cfg, serve_cfg: ServeConfig):
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        self.params = materialize_served_params(
            params, cfg, serve_cfg.bits, serve_cfg.extra_precision)
        self._decode = jax.jit(
            lambda p, st, tok, pos: api.decode_step(p, st, tok, pos, cfg, bits=None)
        )
        self._prefill = jax.jit(
            lambda p, batch: api.prefill(p, batch, cfg, bits=None,
                                         max_len=serve_cfg.max_len)
        )

    def generate(self, prompts: jax.Array, num_tokens: int, extras=None):
        """prompts: (B, S) int32 -> (B, num_tokens) greedy continuation."""
        B, S = prompts.shape
        batch = {"tokens": prompts}
        if extras:
            batch.update(extras)
        logits, state = self._prefill(self.params, batch)
        tok = jnp.argmax(logits[:, -1], axis=-1).reshape(B, 1).astype(jnp.int32)
        out = [tok]
        for i in range(num_tokens - 1):
            logits, state = self._decode(self.params, state, tok,
                                         jnp.asarray(S + i, jnp.int32))
            tok = jnp.argmax(logits[:, -1], axis=-1).reshape(B, 1).astype(jnp.int32)
            out.append(tok)
        return jnp.concatenate(out, axis=1)

    def score(self, tokens: jax.Array, labels: jax.Array) -> float:
        """Mean NLL of labels under the served model (quality evals)."""
        from repro.core.matquant import cross_entropy
        logits, _ = api.forward(self.params, {"tokens": tokens}, self.cfg, bits=None)
        return float(cross_entropy(logits, labels))
