"""Serving engine: weight materialization + continuous-batching facade.

Deployment flow (paper Section 5.4): one int8 *parent* checkpoint is
stored; each layer's weights are sliced to the precision the deployment
demands (uniform int8/6/4/3/2 or a per-layer Mix'n'Match vector) and
served. Execution paths:

  * TPU: the Pallas `quant_matmul` kernel consumes packed planes
    (`materialize_packed_params`, ServeConfig.use_packed) and
    dequantizes in VMEM (kernels/quant_matmul.py).
  * CPU/tests: weights are materialized as their dequantized values
    (`materialize_served_params`) -- numerically IDENTICAL to the
    packed path (test_perf_paths proves it equals fake-quant forward).

Serving architecture
--------------------
`Engine` is a thin facade over the continuous-batching subsystem:

  * serve/scheduler.py -- request queue + slot-array continuous
    batching: admit on free slots, one jitted `decode_step_slots` over
    the full slot array per step (static shapes, per-slot position
    vector), evict on EOS/max-tokens so finished requests release
    capacity mid-flight.
  * serve/kv_cache.py -- the slot/page pool over `api.init_state`'s
    decode-state layout: page-budget admission, allocate/free/defrag,
    and the jit-friendly insert/permute state surgery.
  * serve/router.py -- elastic-precision policy: queue depth + token
    backlog pick the served tier (int8 -> int4 -> Mix'n'Match -> int2),
    re-materialized via the functions below and cached per tier
    (TierEntry) so a switch between two decode steps is a dict lookup.
    With TierCache(packed=True), every tier -- uniform-int, MoE
    expert stacks, and per-layer Mix'n'Match -- is PACKED r-bit
    planes sliced from one pre-packed parent (build_packed_parent),
    so a downgrade swaps the plane the kernel reads -- measured HBM
    weight bytes drop per step -- and the scheduler compiles one
    step per packed representation (bitwidth, or per-layer tuple).
  * serve/metrics.py -- TTFT / latency / throughput / tier-occupancy
    counters the benchmarks serialize.

`Engine.generate` routes fixed batches through the scheduler as the
single-batch special case (token-identical to the legacy loop, kept as
`generate_legacy`); `Engine.scheduler()` hands out the full
continuous-batching interface for arrival-stream drivers
(launch/serve.py, benchmarks/serve_throughput.py).
"""

from __future__ import annotations

import dataclasses
import math
import warnings

import jax
import jax.numpy as jnp

from repro.core import packing as packing_lib
from repro.core import quant
from repro.models import api

# projection names whose 'w' leaf is a quantized (ffn-scope) weight
_FFN_PROJ = {"up", "gate", "down", "wz", "wx", "wo", "wq", "wk", "wv"}
_ATTN_PARENT = {"attn", "self_attn", "cross_attn"}
_FFN_PARENT = {"ffn", "moe", "mamba", "mlstm", "slstm"}


def _path_names(path) -> list[str]:
    out = []
    for k in path:
        name = getattr(k, "key", None)
        if name is None:
            name = getattr(k, "name", None)    # GetAttrKey (PackedPlane)
        if name is None:
            name = getattr(k, "idx", k)
        out.append(str(name))
    return out


def quantized_leaf_kind(path) -> str | None:
    """'ffn' / 'attn' if this param path is a quantizable weight."""
    names = _path_names(path)
    if not names or names[-1] != "w":
        return None
    parents = set(names[:-1])
    proj = names[-2] if len(names) >= 2 else ""
    if parents & _ATTN_PARENT and proj in {"wq", "wk", "wv", "wo"}:
        return "attn"
    if parents & _FFN_PARENT and proj in _FFN_PROJ:
        if proj in {"wq", "wk", "wv"} and "mlstm" not in parents:
            return "attn"
        return "ffn"
    return None


def _scoped(path, qcfg) -> bool:
    """Whether this param path is quantized under the config's scope."""
    kind = quantized_leaf_kind(path)
    return kind == "ffn" or (kind == "attn" and "attn" in qcfg.scope)


def _leaf_group_axis(names, leaf) -> tuple[bool, int]:
    """(stacked, group_axis) of a scoped leaf: whether its leading axis
    is the stacked layer dim, and which axis is the minmax reduction
    dim: (L, E, d_in, d_out) -> 2, (L, d_in, d_out) -> 1,
    (E, d_in, d_out) -> 1, (d_in, d_out) -> 0. Per-layer slices of a
    stacked leaf reduce along group_axis - 1."""
    stacked = names[0] in ("layers", "encoder", "decoder") and leaf.ndim >= 3
    moe = "moe" in names
    if stacked:
        return True, 2 if (moe and leaf.ndim == 4) else 1
    return False, 1 if (moe and leaf.ndim == 3) else 0


def materialize_served_params(params, cfg, bits, extra_precision: bool | None = None):
    """Replace quantized weights with their sliced-dequantized values.

    bits: int (uniform) or per-layer list/array (Mix'n'Match; applied to
    leaves whose leading axis is the stacked layer dim)."""
    qcfg = cfg.quant
    ep = qcfg.extra_precision if extra_precision is None else extra_precision
    per_layer = not isinstance(bits, int)
    if per_layer:
        bits_arr = jnp.asarray(bits, jnp.int32)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        if not _scoped(path, qcfg):
            out.append(leaf)
            continue
        names = _path_names(path)
        stacked, group_axis = _leaf_group_axis(names, leaf)
        if per_layer and stacked:
            qd = jax.vmap(
                lambda w, b: quant.quant_dequant(
                    w, qcfg.parent_bits, b, axis=group_axis - 1,
                    extra_precision=ep)
            )(leaf, bits_arr[: leaf.shape[0]])
        else:
            # scoped leaves OUTSIDE the stacked layer dim (VLM / enc-dec
            # projections) under a per-layer vector: serve them at the
            # MAX of the vector -- the conservative policy (a layer-wise
            # downgrade never degrades shared projections below the
            # best-precision layer they feed)
            b = int(bits) if not per_layer else int(max(int(v) for v in bits))
            qd = quant.quant_dequant(leaf, qcfg.parent_bits, b, axis=group_axis,
                                     extra_precision=ep)
        out.append(qd.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def build_packed_parent(params, cfg):
    """Pack the int8 PARENT codes of every scoped projection once.

    Returns {key-path str: core.packing.PackedLinear}. This is the
    stored artifact of the paper's deployment story (Section 5.4): one
    packed c-bit parent per plane, from which `materialize_packed_params`
    slices any r <= c tier via `PackedLinear.materialize` -- a cheap
    unpack/slice/re-pack instead of a re-quantization of the float
    checkpoint per tier. Covers every scoped leaf regardless of leading
    dims: dense/VLM/encdec (k, n) projections, stacked-layer (L, k, n)
    planes, and MoE expert stacks ((E, k, n) / (L, E, k, n)) --
    `PackedLinear` treats everything before the trailing (k, n) as batch
    dims, and `apply_moe` consumes the per-expert planes batched.
    """
    from repro.core import packing
    qcfg = cfg.quant
    parent = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        if not _scoped(path, qcfg):
            continue
        names = _path_names(path)
        # down-type projections (out dim = residual 'embed') pack along N
        # so the packed plane stays sharded on its reduction dim under
        # TP; everything else packs along K and shards the out dim.
        proj = names[-2] if len(names) >= 2 else ""
        pack_axis = -1 if proj in ("down", "wo") else -2
        parent[jax.tree_util.keystr(path)] = packing.PackedLinear.from_weights(
            leaf.astype(jnp.float32), qcfg.parent_bits, pack_axis=pack_axis)
    return parent


def materialize_packed_params(params, cfg, bits, parent=None,
                              extra_precision: bool = False):
    """Replace quantized weights with PACKED r-bit planes.

    Each scoped 'w' leaf becomes a `core.packing.PackedPlane` (int32
    packed codes along the pack axis, plus alpha/beta; bits and
    pack_axis ride as static metadata): w_hat = alpha * code - beta.
    The int8 parent is quantized per-output-channel, sliced to `bits`
    via `PackedLinear.materialize`, and re-packed -- HBM weight bytes
    drop 16/bits x vs bf16. Consumed by kernels.ops.plane_matmul (the
    Pallas kernel on TPU, its jnp twin elsewhere) through
    common.qlinear / ffn.apply_moe.

    `extra_precision` (Errata Eq. 8) additionally packs the 1-bit
    overflow bitmap onto every plane (PackedPlane.overflow, composed
    in-kernel as the 2^bits-valued term); the dequant fallback path
    applies the overflow bucket numerically instead.

    `bits` is an int (uniform tier) or a per-layer vector (Mix'n'Match):
    the per-layer path unstacks `params['layers']` into a Python list of
    L per-layer subtrees, layer l's planes sliced at bits[l] (packed
    plane shapes depend on r, so a heterogeneous stack cannot stay
    stacked; `models.common.scan_layers` unrolls over the list).
    Scoped leaves outside the layer stack get max(bits) -- the
    conservative policy, matching `materialize_served_params`.

    Any scoped leaf MISSING from `parent` (a layout the packer cannot
    handle) is materialized dequantized at the tier's bits with a
    warning instead of being served raw -- a packed tier must never
    silently include full-precision projections.

    `parent` (from `build_packed_parent`) reuses pre-packed parent
    codes across tiers; by default it is built on the fly.
    """
    if parent is None:
        parent = build_packed_parent(params, cfg)
    if isinstance(bits, int):
        return _materialize_packed_uniform(params, cfg, bits, parent,
                                           extra_precision)
    return _materialize_packed_per_layer(
        params, cfg, [int(b) for b in bits], parent, extra_precision)


def _key_of(entry):
    return getattr(entry, "key", getattr(entry, "idx", None))


def _set_path(d, path, value):
    node = d
    for k in path[:-1]:
        node = node[_key_of(k)]
    node[_key_of(path[-1])] = value


def _dequant_fallback(path, leaf, cfg, bits: int, extra_precision=False):
    """Satellite guard: a scoped projection with no packed parent is
    served DEQUANTIZED at the tier's bits (never raw bf16), loudly."""
    warnings.warn(
        f"packed tier: scoped projection {jax.tree_util.keystr(path)} has "
        f"no packed parent plane; serving it dequantized at {bits} bits "
        f"so the tier's quality numbers do not silently include "
        f"full-precision weights", stacklevel=3)
    _, group_axis = _leaf_group_axis(_path_names(path), leaf)
    return quant.quant_dequant(leaf, cfg.quant.parent_bits, bits,
                               axis=group_axis,
                               extra_precision=extra_precision
                               ).astype(leaf.dtype)


def _materialize_packed_uniform(params, cfg, bits: int, parent, ep: bool):
    qcfg = cfg.quant
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        pl = parent.get(jax.tree_util.keystr(path))
        if pl is not None:
            out.append(pl.materialize_plane(bits, extra_precision=ep))
            continue
        if _scoped(path, qcfg):
            out.append(_dequant_fallback(path, leaf, cfg, bits, ep))
        else:
            out.append(leaf)

    # rebuild by mutating a container-copied tree by key-path (leaf
    # structure changes, so tree_unflatten can't be used directly)
    base = _deep_copy_containers(params)
    for (path, _), new_leaf in zip(flat, out):
        _set_path(base, path, new_leaf)
    return base


def _materialize_packed_per_layer(params, cfg, bits: list[int], parent,
                                  ep: bool):
    """Packed Mix'n'Match tier: per-layer packed planes, layers unstacked.

    `params['layers']` becomes a list of L per-layer subtrees (packed
    plane shapes depend on each layer's r); every other leaf keeps its
    place. Scoped leaves outside the stack serve at max(bits)."""
    qcfg = cfg.quant
    L = cfg.num_layers
    if len(bits) != L:
        raise ValueError(f"per-layer bits {bits} must have one entry per "
                         f"layer ({L})")
    base = _deep_copy_containers(params)
    layers = base.get("layers")
    if not isinstance(layers, dict):
        raise NotImplementedError(
            "packed Mix'n'Match tiers need a stacked 'layers' dict "
            f"(family {cfg.family!r} stores layers differently)")
    # unstack the layer stack into per-layer subtrees, skipping the
    # leaves that become packed planes below (no point materializing L
    # slices of the big weight stacks just to overwrite them)
    replaced = {k for k in parent if k.startswith("['layers']")}

    def unstack(path, a, l):
        if "['layers']" + jax.tree_util.keystr(path) in replaced:
            return None                    # placeholder, overwritten below
        return a[l]

    per = [jax.tree_util.tree_map_with_path(
        lambda p, a: unstack(p, a, l), layers) for l in range(L)]
    b_shared = max(bits)
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        key = jax.tree_util.keystr(path)
        pl = parent.get(key)
        names = _path_names(path)
        if pl is None:
            if not _scoped(path, qcfg):
                continue
            if names[0] == "layers" and leaf.ndim >= 3:
                # stacked scoped leaf with no parent: dequantize each
                # layer at ITS OWN bits[l], matching the dequantized
                # Mix'n'Match tier (materialize_served_params)
                warnings.warn(
                    f"packed tier: scoped projection {key} has no packed "
                    f"parent plane; serving it dequantized at the "
                    f"per-layer bits so the tier's quality numbers do "
                    f"not silently include full-precision weights",
                    stacklevel=2)
                _, group_axis = _leaf_group_axis(names, leaf)
                for l in range(L):
                    qd_l = quant.quant_dequant(
                        leaf[l], qcfg.parent_bits, bits[l],
                        axis=group_axis - 1, extra_precision=ep)
                    _set_path(per[l], path[1:], qd_l.astype(leaf.dtype))
            else:
                _set_path(base, path,
                          _dequant_fallback(path, leaf, cfg, b_shared, ep))
            continue
        # ... then swap each scoped stacked leaf for its layer's plane
        if names[0] == "layers" and leaf.ndim >= 3:
            for l in range(L):
                _set_path(per[l], path[1:],
                          pl.layer(l).materialize_plane(
                              bits[l], extra_precision=ep))
        else:
            _set_path(base, path,
                      pl.materialize_plane(b_shared, extra_precision=ep))
    base["layers"] = per
    return base


def served_param_shardings(params, cfg, mesh):
    """NamedSharding tree for served params on a `(data, model)` mesh.

    Works for BOTH served layouts: packed params (every scoped leaf a
    `PackedPlane`, incl. per-layer Mix'n'Match lists and MoE expert
    stacks) get their specs from `packed_axes` -- K-packed planes shard
    their OUTPUT dim over 'model', N-packed down/wo planes keep their
    reduction-dim shard, overflow bitmaps shard exactly like their
    words -- and dequantized params fall through `packed_axes`
    untouched, resolving the plain `api.axes` specs. Resolution uses
    `runtime.sharding.serving_rules()` (TP-only: no FSDP shard on the
    embed dim, 'data' reserved for request parallelism) at HEAD
    granularity for the attention projections: the flattened
    q_heads/kv_heads dims only shard over 'model' when the head COUNT
    divides it (a 2-kv-head reduced config on model=4 serves wk/wv
    replicated instead of splitting inside a head).
    """
    from repro.runtime import sharding as shard_lib
    ax = packed_axes(api.axes(cfg), params, cfg)
    hd = getattr(cfg, "resolved_head_dim", None) or 1
    return shard_lib.tree_shardings(ax, params, mesh,
                                    rules=shard_lib.serving_rules(),
                                    units={"q_heads": hd, "kv_heads": hd})


def served_nbytes(params, cfg) -> tuple[int, int, int]:
    """(plane_bytes, total_bytes, per_device_plane_bytes), one traversal.

    plane_bytes counts only the sliced code planes -- packed int32
    words plus the extra-precision overflow bitmaps, or the full
    dequantized 'w' arrays on the fallback path -- i.e. the term that
    shrinks 2x per packed tier step (int8 -> int4 -> int2, with
    int2+ep's dense bitmap landing at 3 bits/weight in between).
    total_bytes adds the per-channel alpha/beta scales, which are
    tier-independent. Both are the HBM weight traffic of one decode
    step, the quantity the elastic downgrade is supposed to cut.

    per_device_plane_bytes is the plane term again with each leaf
    contributing its largest single-device shard
    (`sharding.shard_shape`) instead of its global size -- on a TP mesh
    whose 'model' axis divides every plane's sharded dim this is
    exactly plane_bytes / model_parallel, the footprint the TP shard
    actually divides. Unsharded (single-device or replicated) leaves
    contribute their full size, so off-mesh per_device == plane.
    """
    qcfg = cfg.quant

    def shard_nbytes(leaf):
        size = leaf.size
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None:
            size = math.prod(sharding.shard_shape(leaf.shape))
        return int(size) * leaf.dtype.itemsize

    plane = total = per_device = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        names = _path_names(path)
        if (len(names) >= 2 and names[-2] == "w"
                and names[-1] in ("words", "overflow", "alpha", "beta")):
            nb = leaf.size * leaf.dtype.itemsize
            total += nb
            if names[-1] in ("words", "overflow"):
                plane += nb
                per_device += shard_nbytes(leaf)
            continue
        if _scoped(path, qcfg):
            nb = leaf.size * leaf.dtype.itemsize
            plane += nb
            total += nb
            per_device += shard_nbytes(leaf)
    return plane, total, per_device


def served_weight_nbytes(params, cfg) -> tuple[int, int]:
    """(plane_bytes, total_bytes) of the served weights; `served_nbytes`."""
    return served_nbytes(params, cfg)[:2]


def served_plane_nbytes_per_device(params, cfg) -> int:
    """Per-device plane bytes of the served weights; `served_nbytes`."""
    return served_nbytes(params, cfg)[2]


def served_effective_bits(params) -> float | None:
    """Measured Table 7 effective bits/weight of the served PLANES.

    The paper's extra-precision accounting (Errata Eq. 8 / Table 7):
    every weight costs its plane's base r bits, plus ONE extra bit for
    each weight that actually lands in the overflow bucket -- i.e.
    r + popcount(bitmap)/weights, ~2.05-2.2 for int2+ep -- not the
    dense 1-bit-per-weight bitmap we store for simplicity. Weighted
    over all `PackedPlane` leaves (uniform tiers give back their r,
    Mix'n'Match tiers the per-layer weighted mean). Returns None when
    the params carry no packed planes (the dequantized layout).

    Plane sizes are inferred from the word/scale shapes; for a
    K-packed plane the reduction dim is recovered as
    ceil(k/cpw) * cpw, exact whenever k is a multiple of
    codes-per-word (always true for the MXU-aligned model dims the
    kernels require).
    """
    from repro.core import packing

    weights = 0
    bit_sum = 0.0
    planes = jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, packing.PackedPlane))
    for plane in planes:
        if not isinstance(plane, packing.PackedPlane):
            continue
        cpw = packing.codes_per_word(plane.bits)
        if plane.pack_axis in (-2, plane.words.ndim - 2):
            size = plane.words.size * cpw            # lead * k_padded * n
        else:
            n = plane.alpha.shape[-1]                # N-packed: n is exact
            size = plane.words.size // plane.words.shape[-1] * n
        weights += size
        bit_sum += plane.bits * size
        if plane.overflow is not None:
            ovf = plane.overflow.view(jnp.uint32)
            bit_sum += float(jnp.sum(
                jax.lax.population_count(ovf).astype(jnp.float32)))
    return bit_sum / weights if weights else None


def _deep_copy_containers(tree):
    if isinstance(tree, dict):
        return {k: _deep_copy_containers(v) for k, v in tree.items()}
    if isinstance(tree, list):
        return [_deep_copy_containers(v) for v in tree]
    if isinstance(tree, tuple):
        return tuple(_deep_copy_containers(v) for v in tree)
    return tree


def packed_axes(axes_tree, params_packed, cfg):
    """Logical-axes tree matching `materialize_packed_params` output:
    wherever the packed params carry a PackedPlane, the axes leaf
    {'w': (..., a_in, a_out)} becomes a PackedPlane of specs sharded on
    a_out (the packed dim stays unsharded; N-packed down/wo planes keep
    their a_in shard instead). Per-layer Mix'n'Match params store
    'layers' as a list; the stacked axes subtree is replayed per layer
    with the leading 'layer' axis dropped."""
    from repro.core import packing

    def drop_layer(t):
        return t[1:] if t and t[0] == "layer" else t

    def walk(ax_node, p_node, path):
        if isinstance(p_node, packing.PackedPlane):
            # ax_node is the original 'w' spec tuple (..., a_in, a_out)
            spec = tuple(ax_node)
            rest, a_in, a_out = spec[:-2], spec[-2], spec[-1]
            if p_node.pack_axis in (-1, 1):   # packed along N: keep K shard
                words = rest + (a_in, None)
            else:                             # packed along K: keep N shard
                words = rest + (None, a_out)
            scales = rest + (None, a_out)
            return packing.PackedPlane(
                words=words, alpha=scales, beta=scales,
                # the overflow bitmap shards exactly like the words
                overflow=words if p_node.overflow is not None else None,
                bits=p_node.bits, pack_axis=p_node.pack_axis,
                extra_precision=p_node.extra_precision,
                # static slice metadata must ride along or the spec
                # tree's treedef diverges from the aliased draft view's
                slice_bits=p_node.slice_bits, slice_ep=p_node.slice_ep)
        if isinstance(p_node, dict):
            return {k: walk(ax_node[k], p_node[k], path + [k]) for k in p_node}
        if isinstance(p_node, list):
            if isinstance(ax_node, dict):     # per-layer params, stacked axes
                ax_l = jax.tree.map(drop_layer, ax_node,
                                    is_leaf=lambda x: isinstance(x, tuple))
                return [walk(ax_l, v, path + [i])
                        for i, v in enumerate(p_node)]
            return [walk(a, v, path + [i])
                    for i, (a, v) in enumerate(zip(ax_node, p_node))]
        return ax_node

    return walk(axes_tree, params_packed, [])


@dataclasses.dataclass
class ServeConfig:
    bits: object = 8                 # int or per-layer list (Mix'n'Match)
    max_len: int = 512
    extra_precision: bool = False
    use_packed: bool = False         # TPU kernel path (packed r-bit planes)
    num_slots: int = 8               # continuous batching: concurrent requests
    page_size: int = 16              # KV page granularity (tokens)
    keep_parent: bool = True         # retain parent ckpt for elastic tiers;
                                     # False frees it (elastic then raises)
    # paged KV cache (None = dense slot-array state, the legacy layout):
    # "fp" pages at model dtype, 8/4/2 int8 pages attended at that slice,
    # "auto" ties the KV read width to the served weight tier
    kv_bits: object = None
    kv_page_size: int | None = None  # defaults to page_size when paged
    prefix_cache: bool = False       # radix prompt-prefix page sharing
    attn_kernel: str = "fused"       # paged decode attend: "fused" Pallas
                                     # kernel off the page store, "gather"
                                     # the materialize-then-attend fallback

    def kv_config(self):
        """`kv_cache.KVCacheConfig` for the paged path, or None."""
        if self.kv_bits is None and not self.prefix_cache:
            return None
        from repro.serve.kv_cache import KVCacheConfig
        return KVCacheConfig(
            kv_bits=self.kv_bits if self.kv_bits is not None else "fp",
            page_size=self.kv_page_size or self.page_size,
            prefix_cache=self.prefix_cache,
            attn_kernel=self.attn_kernel)


def _packed_backend_ok() -> bool:
    """Packed planes pay off where the Pallas kernel runs (TPU)."""
    return jax.default_backend() == "tpu"


class Engine:
    """Facade over the continuous-batching scheduler (see module doc).

    Holds the materialized served weights for the configured tier and
    the jitted legacy prefill/decode closures; `generate`/`score` keep
    their original signatures.

    `mesh` (optional, a `(data, model)` mesh -- `launch.mesh.
    make_host_mesh` / `make_production_mesh`) places the served params
    with `served_param_shardings` and threads through to every
    scheduler this engine builds: packed tier planes shard their
    unpacked dim over 'model' (per-device plane bytes divide by the
    model-parallel degree), the KV slot state shards batch-over-'data'
    and heads-over-'model', and every tier the elastic cache
    materializes lands directly in sharded buffers. The degenerate
    1-device mesh is valid and runs the same code path.
    """

    def __init__(self, params, cfg, serve_cfg: ServeConfig, mesh=None):
        self.serve_cfg = serve_cfg
        self.mesh = mesh
        # tier re-materialization source; note the extra reference only
        # pins the caller's arrays, it copies nothing
        self._parent_params = params if serve_cfg.keep_parent else None
        use_packed = serve_cfg.use_packed
        if use_packed and not _packed_backend_ok():
            warnings.warn(
                "ServeConfig.use_packed: no TPU backend, so the Pallas "
                "quant_matmul path is unavailable; serving dequantized "
                "weights instead", stacklevel=2)
            use_packed = False
        self.packed = use_packed
        bits = serve_cfg.bits
        # hashable representation key: int (uniform) / per-layer tuple
        # (Mix'n'Match) / (key, "ep") with the overflow bitmap
        self._packed_key = packing_lib.packed_rep_key(
            bits, serve_cfg.extra_precision) if use_packed else None
        if use_packed:
            cfg = cfg.replace(quant=dataclasses.replace(
                cfg.quant,
                packed_bits=bits if isinstance(bits, int) else 0,
                # the Pallas kernel itself only pays off where it
                # compiles; elsewhere packed planes run the jnp twin
                packed_kernel=jax.default_backend() == "tpu"))
            self.params = materialize_packed_params(
                params, cfg, bits if isinstance(bits, int) else list(bits),
                extra_precision=serve_cfg.extra_precision)
        else:
            self.params = materialize_served_params(
                params, cfg, bits, serve_cfg.extra_precision)
        if mesh is not None:
            self._shardings = served_param_shardings(self.params, cfg, mesh)
            self.params = jax.device_put(self.params, self._shardings)
        else:
            self._shardings = None
        self.cfg = cfg
        self._decode = jax.jit(
            lambda p, st, tok, pos: api.decode_step(p, st, tok, pos, cfg, bits=None)
        )
        self._prefill = jax.jit(
            lambda p, batch: api.prefill(p, batch, cfg, bits=None,
                                         max_len=serve_cfg.max_len)
        )
        self._score_logits = jax.jit(
            lambda p, toks: api.forward(p, {"tokens": toks}, cfg, bits=None)[0]
        )
        self._schedulers: dict[tuple[int, int], object] = {}

    # -- continuous batching ----------------------------------------------

    def scheduler(self, *, num_slots: int | None = None,
                  max_len: int | None = None, elastic: bool = False,
                  managed: bool = False,
                  tiers=None, thresholds=None, cooldown: int = 4,
                  total_pages: int | None = None, clock=None,
                  packed: bool | None = None, spec_decode=None):
        """Build a ContinuousBatchingScheduler over this engine's model.

        elastic=True serves load-adaptive precision from the parent
        checkpoint (router + per-tier cache); otherwise the scheduler
        serves this engine's fixed tier (packed or dequantized).

        managed=True builds the SAME elastic tier cache but no local
        router: the scheduler starts at tiers[0] and an external policy
        owns every switch through `set_tier` -- the mode one fleet
        replica runs in, where serve/fleet.py's global FleetRouter
        assigns per-replica tiers (`thresholds`/`cooldown` are router
        parameters and are rejected here).

        `packed` (elastic only; defaults to this engine's use_packed
        resolution) materializes every tier as packed r-bit planes -- a
        router downgrade then swaps the plane the kernel reads, cutting
        HBM weight bytes per step, with one compiled prefill/decode
        closure per representation key (the bitwidth for uniform tiers,
        the per-layer bits tuple for Mix'n'Match tiers, whose layers are
        served as per-layer packed planes).

        `spec_decode` (a `serve.specdecode.SpecDecodeConfig`) turns on
        Matryoshka self-speculative decoding: a low-bit slice of the
        SAME resident parent drafts draft_len tokens per round and the
        serving tier verifies the whole block in one step -- token-
        exact vs plain decode, fewer verify-model steps per token. On
        the packed path the draft plane aliases the resident tier's
        bytes (`core.packing.sliced_view`); the dequantized fallback
        materializes draft weights from the parent checkpoint.
        """
        from repro.serve import router as router_mod
        from repro.serve import scheduler as sched_mod
        kw = dict(
            num_slots=num_slots or self.serve_cfg.num_slots,
            max_len=max_len or self.serve_cfg.max_len,
            page_size=self.serve_cfg.page_size,
            total_pages=total_pages,
            kv=self.serve_cfg.kv_config(),
            mesh=self.mesh,
        )
        if spec_decode is not None:
            kw["spec_decode"] = spec_decode
            kw["draft_source"] = self._parent_params
        if clock is not None:
            kw["clock"] = clock
        if elastic and managed:
            raise ValueError("elastic (self-routed) and managed "
                             "(fleet-routed) are mutually exclusive")
        if managed and (thresholds is not None or cooldown != 4):
            raise ValueError("managed schedulers have no local router; "
                             "thresholds/cooldown belong to the fleet's "
                             "FleetRouter")
        if elastic or managed:
            if self._parent_params is None:
                raise ValueError("elastic tiers re-materialize from the "
                                 "parent checkpoint, which this engine was "
                                 "built without (keep_parent=False)")
            packed = self.packed if packed is None else packed
            tiers = tiers or router_mod.default_tiers(self.cfg.num_layers)
            cache = router_mod.TierCache(
                self._parent_params, self.cfg,
                extra_precision=self.serve_cfg.extra_precision,
                packed=packed, mesh=self.mesh)
            own = self.serve_cfg.bits
            own = tuple(own) if isinstance(own, (list, tuple)) else own
            own_ep = self.serve_cfg.extra_precision
            for tier in tiers:
                # this engine's fixed tier is already materialized --
                # seed the cache instead of re-quantizing a second copy
                # (only when the stored representation matches what the
                # cache would build for that tier: same bits AND same
                # effective extra-precision -- the cache-wide ep flag
                # promotes every tier; with packed=True every tier --
                # uniform, Mix'n'Match, or ep -- is packed)
                tb = tier.bits if isinstance(tier.bits, int) else tuple(tier.bits)
                tier_ep = tier.extra_precision or self.serve_cfg.extra_precision
                if tb != own or tier_ep != own_ep:
                    continue
                if packed == self.packed:
                    cache.seed(tier, self.params,
                               packed_bits=self._packed_key)
            if managed:
                return sched_mod.ContinuousBatchingScheduler(
                    None, self.cfg, tier_cache=cache, tier=tiers[0], **kw)
            return sched_mod.ContinuousBatchingScheduler(
                None, self.cfg,
                router=router_mod.ElasticPrecisionRouter(
                    tiers, thresholds, cooldown=cooldown),
                tier_cache=cache,
                **kw)
        return sched_mod.ContinuousBatchingScheduler(
            self.params, self.cfg, packed_bits=self._packed_key,
            param_shardings=self._shardings, **kw)

    def _batch_scheduler(self, B: int, max_len: int, spec_decode=None):
        # keep only the latest shape: each cached scheduler pins a full
        # (L, B, max_len, ...) decode state on device
        key = (B, max_len, spec_decode)
        if key not in self._schedulers:
            self._schedulers.clear()
            self._schedulers[key] = self.scheduler(num_slots=B, max_len=max_len,
                                                   spec_decode=spec_decode)
        sched = self._schedulers[key]
        sched.reset()
        return sched

    # -- generation --------------------------------------------------------

    def generate(self, prompts: jax.Array, num_tokens: int, extras=None,
                 spec_decode=None):
        """prompts: (B, S) int32 -> (B, num_tokens) greedy continuation.

        Routed through the continuous-batching scheduler as the
        all-arrive-at-once special case (dense / vlm / moe -- MoE
        dispatch is row-local, see `ffn.apply_moe`, so slot rows never
        couple and the scheduler path matches the legacy loop here,
        where every prompt shares one length; mixed-length MoE traffic
        sees the intra-row padding caveat in the scheduler module doc);
        requests needing per-request extras keep the legacy fixed-batch
        loop.

        The whole batch is admitted in one step, so admission costs one
        bucketed prefill per prompt-length bucket (a single call here,
        where every prompt shares one length) -- same launch count as
        `generate_legacy`, which remains the equivalence oracle.

        `spec_decode` (a `serve.specdecode.SpecDecodeConfig`) drafts
        with a low-bit slice of the same parent and verifies with this
        engine's tier -- token-identical output, fewer verify steps.
        """
        if extras or self.cfg.family not in ("dense", "vlm", "moe"):
            if spec_decode is not None:
                raise NotImplementedError(
                    "spec decode rides the slot scheduler; unavailable on "
                    "the legacy fixed-batch path")
            return self.generate_legacy(prompts, num_tokens, extras)
        import numpy as np
        from repro.serve.scheduler import Request
        B, S = prompts.shape
        sched = self._batch_scheduler(B, S + num_tokens, spec_decode)
        prompts_np = np.asarray(prompts)
        for i in range(B):
            sched.submit(Request(uid=i, prompt=prompts_np[i],
                                 max_new_tokens=num_tokens))
        results = sched.run_until_idle()
        return jnp.asarray(np.stack([results[i] for i in range(B)]))

    def generate_legacy(self, prompts: jax.Array, num_tokens: int, extras=None):
        """The original fixed-batch run-to-completion loop (also the
        equivalence oracle for the scheduler path)."""
        B, S = prompts.shape
        batch = {"tokens": prompts}
        if extras:
            batch.update(extras)
        logits, state = self._prefill(self.params, batch)
        tok = jnp.argmax(logits[:, -1], axis=-1).reshape(B, 1).astype(jnp.int32)
        out = [tok]
        for i in range(num_tokens - 1):
            logits, state = self._decode(self.params, state, tok,
                                         jnp.asarray(S + i, jnp.int32))
            tok = jnp.argmax(logits[:, -1], axis=-1).reshape(B, 1).astype(jnp.int32)
            out.append(tok)
        return jnp.concatenate(out, axis=1)

    def score(self, tokens: jax.Array, labels: jax.Array) -> float:
        """Mean NLL of labels under the served model (quality evals)."""
        from repro.core.matquant import cross_entropy
        logits = self._score_logits(self.params, tokens)
        return float(cross_entropy(logits, labels))
