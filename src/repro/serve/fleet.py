"""Replica fleet serving: N data-parallel replicas, one global router.

One MatQuant parent checkpoint serves every precision; PRs 1-8 built a
single elastic replica. This module scales the deployment axis: a
`Fleet` owns N `Engine` replicas behind ONE global admission queue,
and the elastic policy goes global -- `serve.router.FleetRouter` maps
one fleet-wide load signal to a PER-REPLICA tier assignment, so a load
spike downgrades the least-loaded replicas first while >= 1 pinned
replica stays at int4-or-better for priority traffic. Each replica
runs a fleet-managed scheduler (`Engine.scheduler(managed=True)`):
same tier cache, same one-compile-per-representation-key closures, but
the tier knob is driven from outside through `set_tier`.

Two replica transports, one interface:

  * `Replica` -- in-process: an Engine + managed scheduler over its own
    device-subset mesh (`launch.mesh.make_replica_meshes`; under
    XLA_FLAGS=--xla_force_host_platform_device_count=8 every replica
    owns real devices, on a bare single-device host they share one
    degenerate mesh). This is the default and what the benchmarks
    replay on.
  * `SubprocessReplica` -- true multi-process validation: a worker
    process (`python -m repro.serve.fleet --worker`) builds its own
    engine and speaks a JSON-lines protocol on stdin/stdout, beating a
    `runtime.fault.Heartbeat` file per step. SIGKILLing the worker is
    a REAL process death, which is what the kill/requeue tests
    exercise end to end.

Failure semantics (the zero-request-loss contract): every request a
replica holds is also tracked fleet-side, so when a replica fails --
its process exited, its heartbeat went stale
(`Heartbeat.stale(timeout)`), or its `StepMonitor` flagged it as a
chronic straggler -- the fleet drains it and requeues the ORIGINAL
requests (full prompt, full budget) onto survivors. Partial
generations are discarded on purpose: greedy decode is deterministic,
so the replay reproduces token-identical outputs, and `FleetMetrics.
summary()["requests_lost"]` stays 0.
"""

from __future__ import annotations

import collections
import json
import os
import subprocess
import sys
import time

import numpy as np

from repro.runtime.fault import Heartbeat, StepMonitor
from repro.serve.metrics import FleetMetrics
from repro.serve.router import FleetRouter, default_tiers
from repro.serve.scheduler import Request

__all__ = ["Fleet", "Replica", "SubprocessReplica", "ReplicaFailed",
           "build_fleet"]


class ReplicaFailed(RuntimeError):
    """A replica transport died mid-operation (process exit / EOF)."""


class Replica:
    """In-process fleet replica: one Engine + one managed scheduler.

    The fleet never reaches into the scheduler directly; this wrapper
    tracks every submitted-but-unfinished Request (`inflight`) so a
    kill can requeue without trusting the dead scheduler's state, and
    harvests finished results inside `step` so no completed output is
    ever stranded between a step and a failure check.
    """

    def __init__(self, rid: int, engine, tiers, *, num_slots=None,
                 max_len=None, clock=time.perf_counter, heartbeat=None,
                 monitor: StepMonitor | None = None):
        self.rid = rid
        self.engine = engine
        self.tiers = tuple(tiers)
        self.sched = engine.scheduler(managed=True, tiers=self.tiers,
                                      num_slots=num_slots, max_len=max_len,
                                      clock=clock)
        self.clock = clock
        self.heartbeat = heartbeat
        self.monitor = monitor
        self.alive = True
        self.killed = False
        self.wedged = False      # test hook: hung-but-not-dead process
        self._inflight: dict[object, Request] = {}
        self._steps = 0
        if self.heartbeat is not None:
            self.heartbeat.beat(0)   # baseline: never-beaten reads stale

    @property
    def tier_name(self) -> str:
        return self.sched.tier_name

    def load(self) -> float:
        return self.sched.load_signal() + len(self.sched.active)

    def submit(self, req: Request, now: float | None = None):
        self._inflight[req.uid] = req
        self.sched.submit(req, now=now)

    def set_tier(self, index: int):
        self.sched.set_tier(self.tiers[index])

    def step(self, now: float | None = None) -> dict:
        """One scheduler step; returns {uid: np.ndarray} finished now."""
        if self.killed or self.wedged or not self.alive:
            return {}
        self._steps += 1
        self.sched.step(now=now)
        if self.heartbeat is not None:
            self.heartbeat.beat(self._steps)
        finished = self.sched.results
        self.sched.results = {}
        for uid in finished:
            self._inflight.pop(uid, None)
        return finished

    def inflight(self) -> list[Request]:
        return list(self._inflight.values())

    def drain(self) -> list[Request]:
        """Evacuate for requeue. A live replica frees its slots/pages
        via the scheduler; a killed one is abandoned wholesale and the
        fleet-side inflight copy is the source of truth."""
        if not self.killed:
            self.sched.drain_requests()
        out = list(self._inflight.values())
        self._inflight.clear()
        return out

    def kill(self):
        """Simulate abrupt death (the in-process stand-in for SIGKILL)."""
        self.killed = True

    def failure_reason(self, heartbeat_timeout=None, now=None):
        if self.killed:
            return "killed"
        if (heartbeat_timeout is not None and self.heartbeat is not None
                and self.heartbeat.stale(heartbeat_timeout, now=now)):
            return "heartbeat-stale"
        return None

    def close(self):
        self.alive = False


class SubprocessReplica:
    """Fleet replica living in its own OS process (true multi-process).

    The worker (`_worker_main`) builds an engine from the SAME
    (arch, seed) the parent used -- `models.api.init` is deterministic,
    so both sides hold identical weights -- and serves a managed
    scheduler over a JSON-lines pipe protocol:

        {"cmd": "submit", "uid": .., "prompt": [..], "max_new_tokens": n,
         "eos_id": .., "priority": false}
        {"cmd": "step"}      -> {"worked": b, "finished": [[uid, [t..]]..],
                                 "load": f, "tier": name}
        {"cmd": "set_tier", "index": i}
        {"cmd": "stop"}

    Health is observed two ways: `proc.poll()` catches a dead process
    (SIGKILL closes the pipe, so the next read sees EOF immediately),
    and the worker's per-step `Heartbeat` file catches a hung-but-alive
    one. Requests are mirrored parent-side; a finished result only
    leaves `inflight` when its step response arrives, so a worker dying
    between computing and reporting a result still requeues it -- the
    deterministic replay makes that safe.
    """

    def __init__(self, rid: int, *, arch: str, seed: int = 0,
                 reduced: bool = True, num_layers: int | None = None,
                 num_slots: int = 4, max_len: int = 64,
                 heartbeat_path: str | None = None,
                 rpc_timeout: float = 600.0, env=None):
        self.rid = rid
        self.alive = True
        self.killed = False
        self.monitor: StepMonitor | None = None
        self._inflight: dict[object, Request] = {}
        self._last_load = 0.0
        self._pending = 0
        self._tier = "int8"
        self.rpc_timeout = rpc_timeout
        self.heartbeat = (Heartbeat(heartbeat_path)
                          if heartbeat_path else None)
        # a -c entry, not `-m repro.serve.fleet`: the package __init__
        # imports this module, so runpy would warn about the double
        # import before executing it as __main__
        cmd = [sys.executable, "-c",
               "import sys; from repro.serve.fleet import _worker_main; "
               "sys.exit(_worker_main(sys.argv[1:]))",
               "--worker", "--arch", arch, "--seed", str(seed),
               "--num-slots", str(num_slots), "--max-len", str(max_len)]
        if reduced:
            cmd.append("--reduced")
        if num_layers:
            cmd += ["--layers", str(num_layers)]
        if heartbeat_path:
            cmd += ["--heartbeat", heartbeat_path]
        wenv = dict(os.environ if env is None else env)
        src = os.path.join(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))))), "src")
        wenv["PYTHONPATH"] = src + os.pathsep + wenv.get("PYTHONPATH", "")
        wenv.setdefault("JAX_PLATFORMS", "cpu")
        # one plain CPU device per worker: DP parallelism comes from the
        # processes themselves, not from a forced in-process device count
        wenv.pop("XLA_FLAGS", None)
        self.proc = subprocess.Popen(cmd, stdin=subprocess.PIPE,
                                     stdout=subprocess.PIPE, env=wenv,
                                     text=True, bufsize=1)
        ready = self._read(self.rpc_timeout)
        if not ready or not ready.get("ready"):
            raise ReplicaFailed(f"replica {rid}: worker failed to start")

    # -- transport ---------------------------------------------------------

    def _read(self, timeout: float):
        import select
        r, _, _ = select.select([self.proc.stdout], [], [], timeout)
        if not r:
            raise ReplicaFailed(f"replica {self.rid}: rpc timeout")
        line = self.proc.stdout.readline()
        if not line:                     # EOF: the worker died
            raise ReplicaFailed(f"replica {self.rid}: worker EOF")
        return json.loads(line)

    def _rpc(self, cmd: dict) -> dict:
        try:
            self.proc.stdin.write(json.dumps(cmd) + "\n")
            self.proc.stdin.flush()
            return self._read(self.rpc_timeout)
        except (BrokenPipeError, OSError, ReplicaFailed):
            self.killed = True
            raise ReplicaFailed(f"replica {self.rid}: worker gone")

    # -- replica interface -------------------------------------------------

    @property
    def tier_name(self) -> str:
        return self._tier

    def load(self) -> float:
        return self._last_load + self._pending

    def submit(self, req: Request, now: float | None = None):
        self._inflight[req.uid] = req
        self._pending += 1
        self._rpc({"cmd": "submit", "uid": req.uid,
                   "prompt": [int(t) for t in req.prompt],
                   "max_new_tokens": req.max_new_tokens,
                   "eos_id": req.eos_id, "priority": req.priority})

    def set_tier(self, index: int):
        self._tier = self._rpc({"cmd": "set_tier",
                                "index": int(index)})["tier"]

    def step(self, now: float | None = None) -> dict:
        if self.killed or not self.alive:
            return {}
        resp = self._rpc({"cmd": "step"})
        self._last_load = float(resp["load"])
        self._pending = 0
        self._tier = resp["tier"]
        finished = {}
        for uid, toks in resp["finished"]:
            key = next((k for k in self._inflight if k == uid), uid)
            finished[key] = np.asarray(toks, np.int32)
            self._inflight.pop(key, None)
        return finished

    def inflight(self) -> list[Request]:
        return list(self._inflight.values())

    def drain(self) -> list[Request]:
        out = list(self._inflight.values())
        self._inflight.clear()
        return out

    def kill(self):
        self.killed = True
        self.proc.kill()
        try:
            self.proc.wait(timeout=5)
        except subprocess.TimeoutExpired:
            pass

    def failure_reason(self, heartbeat_timeout=None, now=None):
        if self.killed or self.proc.poll() is not None:
            return "exited"
        if (heartbeat_timeout is not None and self.heartbeat is not None
                and self.heartbeat.stale(heartbeat_timeout)):
            return "heartbeat-stale"
        return None

    def close(self):
        self.alive = False
        if self.proc.poll() is None:
            try:
                self.proc.stdin.write(json.dumps({"cmd": "stop"}) + "\n")
                self.proc.stdin.flush()
            except (BrokenPipeError, OSError):
                pass
            try:
                self.proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                self.proc.kill()


class Fleet:
    """N replicas behind one global admission queue + FleetRouter.

    Per `step()`:

      1. HEALTH -- poll each live replica (`failure_reason`: killed /
         exited / stale heartbeat / chronic straggler); failed replicas
         are drained, their in-flight requests requeued to the FRONT of
         the global queue, and retired from dispatch.
      2. ROUTE -- global load (queue depth + every live replica's load
         signal) feeds `FleetRouter.observe`; changed per-replica
         assignments are pushed down via `set_tier` (a cache lookup +
         jit-cache hit after each representation's first visit).
      3. DISPATCH -- drain the global queue: priority requests go to
         the least-loaded PINNED replica (never below the router's
         int4 pin floor), everything else to the least-loaded live
         replica.
      4. STEP -- one scheduler step per live replica; finished results
         are harvested into `self.results` immediately, and each step's
         wall duration feeds the replica's `StepMonitor`.

    `straggler_retire` (off by default) turns the StepMonitor signal
    into the same drain-and-requeue path a kill takes: a replica
    flagged that many times is treated as failed.
    """

    def __init__(self, replicas, tiers, *, thresholds=None,
                 cooldown: int = 4, pinned=(0,), pin_floor: int = 1,
                 heartbeat_timeout: float | None = None,
                 straggler_retire: int = 0,
                 clock=time.perf_counter):
        self.replicas = list(replicas)
        assert self.replicas
        self.tiers = tuple(tiers)
        self.router = FleetRouter(self.tiers, len(self.replicas),
                                  thresholds=thresholds, cooldown=cooldown,
                                  pinned=pinned, pin_floor=pin_floor)
        self.heartbeat_timeout = heartbeat_timeout
        self.straggler_retire = straggler_retire
        self.clock = clock
        self.queue: collections.deque[Request] = collections.deque()
        self.results: dict[object, np.ndarray] = {}
        self.metrics = FleetMetrics()
        self._applied = [0] * len(self.replicas)
        self._straggles = [0] * len(self.replicas)
        self._step_no = 0
        for rep in self.replicas:
            if rep.monitor is None and isinstance(rep, Replica):
                rep.monitor = StepMonitor()

    # -- intake ------------------------------------------------------------

    def submit(self, req: Request, now: float | None = None):
        now = self.clock() if now is None else now
        self.metrics.on_submit(req.uid, now, req.prompt.size,
                               priority=req.priority)
        self.queue.append(req)

    def live(self) -> list:
        return [r for r in self.replicas if r.alive]

    def load_signal(self) -> float:
        return len(self.queue) + sum(r.load() for r in self.live())

    # -- failure handling --------------------------------------------------

    def _retire(self, rep, reason: str, now: float):
        requeued = rep.drain()
        rep.alive = False
        # hard-kill, not graceful stop: a hung worker (stale heartbeat)
        # would never answer a stop command
        rep.kill()
        self.metrics.on_replica_failure(rep.rid, reason, now)
        if requeued:
            self.metrics.on_requeue([r.uid for r in requeued],
                                    rep.rid, now)
            # front of the queue: evacuated requests were admitted first
            self.queue.extendleft(reversed(requeued))

    def _check_health(self, now: float):
        for i, rep in enumerate(self.replicas):
            if not rep.alive:
                continue
            reason = rep.failure_reason(self.heartbeat_timeout, now=now)
            if reason is None and (self.straggler_retire
                                   and self._straggles[i]
                                   >= self.straggler_retire):
                reason = "straggler"
            if reason is not None:
                self._retire(rep, reason, now)

    def kill(self, rid: int):
        """Hard-kill one replica (bench/test hook); the next step's
        health phase drains and requeues it."""
        self.replicas[rid].kill()

    # -- routing + dispatch ------------------------------------------------

    def _route(self):
        loads = [r.load() if r.alive else float("inf")
                 for r in self.replicas]
        self.router.observe(self.load_signal(), loads)
        for i, rep in enumerate(self.replicas):
            want = self.router.indices[i]
            if rep.alive and want != self._applied[i]:
                rep.set_tier(want)
                self._applied[i] = want

    def _pick(self, candidates):
        return min(candidates, key=lambda r: (r.load(), r.rid))

    def _dispatch(self, now: float):
        live = self.live()
        if not live:
            if self.queue:
                raise RuntimeError("fleet has no live replicas left but "
                                   f"{len(self.queue)} queued request(s)")
            return 0
        pinned_live = [r for r in live if r.rid in self.router.pinned]
        n = 0
        while self.queue:
            req = self.queue.popleft()
            if req.priority and pinned_live:
                rep = self._pick(pinned_live)
            elif req.priority:
                # every pinned replica is gone: best-bits fallback keeps
                # priority traffic as high-precision as the fleet can
                rep = min(live, key=lambda r: (self.router.indices[r.rid],
                                               r.load(), r.rid))
            else:
                rep = self._pick(live)
            rep.submit(req, now=now)
            self.metrics.on_dispatch(req.uid, rep.rid,
                                     self.router.indices[rep.rid], now)
            n += 1
        return n

    # -- the loop ----------------------------------------------------------

    def step(self, now: float | None = None) -> bool:
        now = self.clock() if now is None else now
        self._step_no += 1
        self._check_health(now)
        self._route()
        dispatched = self._dispatch(now)
        finished_any = 0
        worked = False
        for i, rep in enumerate(self.replicas):
            if not rep.alive:
                continue
            t0 = self.clock()
            finished = rep.step(now=now)
            dt = self.clock() - t0
            monitor = getattr(rep, "monitor", None)
            if monitor is not None and monitor.record(self._step_no, dt):
                self._straggles[i] += 1
                self.metrics.on_straggler(rep.rid)
            worked = worked or bool(finished) or bool(rep.inflight())
            t_fin = self.clock()
            for uid, toks in finished.items():
                self.results[uid] = toks
                self.metrics.on_finish(uid, t_fin, int(len(toks)))
                finished_any += 1
        alive = {r.rid: r for r in self.live()}
        self.metrics.on_step(
            {rid: r.tier_name for rid, r in alive.items()},
            {rid: self.router.indices[rid] for rid in alive},
            self.router.mean_effective_bits(), len(self.queue))
        return bool(dispatched or finished_any or worked)

    def has_work(self) -> bool:
        return bool(self.queue) or any(r.inflight() for r in self.live())

    def run_until_idle(self, max_steps: int = 100_000):
        steps = 0
        while self.has_work():
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("fleet did not drain")
        return self.results

    def run_trace(self, trace, max_steps: int = 1_000_000,
                  on_step=None):
        """Replay (offset_seconds, Request) arrivals through the fleet
        (open loop; same virtual-clock fallback as the scheduler's
        `run_trace`). `on_step(fleet, step_index)` is a bench hook --
        e.g. kill a replica at a fixed point in the replay."""
        trace = sorted(trace, key=lambda it: it[0])
        t0 = self.clock()
        i = 0
        steps = 0
        virtual = False
        while i < len(trace) or self.has_work():
            now = self.clock()
            while i < len(trace) and t0 + trace[i][0] <= now:
                self.submit(trace[i][1], now=t0 + trace[i][0])
                i += 1
            if not self.step() and i < len(trace):
                wait = t0 + trace[i][0] - self.clock()
                if wait > 0:
                    if not virtual:
                        time.sleep(min(wait, 0.05))
                        virtual = self.clock() <= now
                    if virtual:
                        self.submit(trace[i][1], now=self.clock())
                        i += 1
            if on_step is not None:
                on_step(self, steps)
            steps += 1
            if steps > max_steps:
                raise RuntimeError("fleet trace replay did not drain")
        return self.results

    def close(self):
        for rep in self.replicas:
            rep.close()


def build_fleet(params, cfg, *, replicas: int, num_slots: int = 4,
                max_len: int = 64, tiers=None, thresholds=None,
                cooldown: int = 4, pinned=(0,), pin_floor: int = 1,
                heartbeat_dir: str | None = None,
                heartbeat_timeout: float | None = None,
                straggler_retire: int = 0, clock=time.perf_counter,
                engine_kwargs=None) -> Fleet:
    """Build an in-process fleet: one Engine per replica over disjoint
    device subsets (`launch.mesh.make_replica_meshes`; on a bare
    single-device host all replicas share the default device)."""
    import jax

    from repro.launch.mesh import make_replica_meshes
    from repro.serve.engine import Engine, ServeConfig

    tiers = tuple(tiers) if tiers else default_tiers(cfg.num_layers)
    meshes = (make_replica_meshes(replicas)
              if len(jax.devices()) > 1 else [None] * replicas)
    reps = []
    for rid in range(replicas):
        engine = Engine(params, cfg,
                        ServeConfig(bits=8, max_len=max_len,
                                    num_slots=num_slots,
                                    **(engine_kwargs or {})),
                        mesh=meshes[rid])
        hb = None
        if heartbeat_dir is not None:
            hb = Heartbeat(os.path.join(heartbeat_dir,
                                        f"replica-{rid}.json"), clock=clock)
        reps.append(Replica(rid, engine, tiers, num_slots=num_slots,
                            max_len=max_len, clock=clock, heartbeat=hb))
    return Fleet(reps, tiers, thresholds=thresholds, cooldown=cooldown,
                 pinned=pinned, pin_floor=pin_floor,
                 heartbeat_timeout=heartbeat_timeout,
                 straggler_retire=straggler_retire, clock=clock)


# -- subprocess worker -------------------------------------------------------

def _worker_main(argv=None) -> int:
    """`python -m repro.serve.fleet --worker`: one replica, JSON-lines
    protocol on stdin/stdout (see SubprocessReplica). stdout carries
    ONLY protocol lines; jax warnings go to stderr."""
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true", required=True)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--num-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--heartbeat", default=None)
    args = ap.parse_args(argv)

    import jax

    from repro.configs import get_config
    from repro.models import api
    from repro.serve.engine import Engine, ServeConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.layers:
        cfg = cfg.replace(num_layers=args.layers)
    params = api.init(jax.random.PRNGKey(args.seed), cfg)
    engine = Engine(params, cfg, ServeConfig(bits=8, max_len=args.max_len,
                                             num_slots=args.num_slots))
    tiers = default_tiers(cfg.num_layers)
    sched = engine.scheduler(managed=True, tiers=tiers)
    hb = Heartbeat(args.heartbeat) if args.heartbeat else None
    if hb is not None:
        hb.beat(0)
    steps = 0

    def reply(obj):
        sys.stdout.write(json.dumps(obj) + "\n")
        sys.stdout.flush()

    reply({"ready": True, "tier": sched.tier_name})
    for line in sys.stdin:
        line = line.strip()
        if not line:
            continue
        cmd = json.loads(line)
        op = cmd.get("cmd")
        if op == "submit":
            sched.submit(Request(uid=cmd["uid"],
                                 prompt=np.asarray(cmd["prompt"], np.int32),
                                 max_new_tokens=int(cmd["max_new_tokens"]),
                                 eos_id=cmd.get("eos_id"),
                                 priority=bool(cmd.get("priority"))))
            reply({"ok": True})
        elif op == "step":
            steps += 1
            worked = sched.step()
            if hb is not None:
                hb.beat(steps)
            finished = [[uid, [int(t) for t in toks]]
                        for uid, toks in sched.results.items()]
            sched.results = {}
            reply({"worked": bool(worked), "finished": finished,
                   "load": sched.load_signal() + len(sched.active),
                   "tier": sched.tier_name})
        elif op == "set_tier":
            sched.set_tier(tiers[int(cmd["index"])])
            reply({"ok": True, "tier": sched.tier_name})
        elif op == "stop":
            reply({"ok": True})
            break
        else:
            reply({"error": f"unknown cmd {op!r}"})
    return 0


if __name__ == "__main__":
    sys.exit(_worker_main())
