"""Slot/page pool over the model decode state (continuous batching).

The decode state produced by `api.init_state(cfg, num_slots, capacity)`
is a fixed-shape pytree whose "batch" axis is a SLOT ARRAY: row i holds
the KV cache (and/or recurrent state) of whatever request currently owns
slot i. Static shapes keep a single jitted decode step alive for the
whole serving session; requests come and go by overwriting rows.

Two layers live here:

  * `PagePool` -- pure-Python accounting. Slots are the unit of
    occupancy (one request per slot); pages (page_size tokens each) are
    the unit of memory budget. The pool may be *overcommitted*
    (total_pages < num_slots * pages_per_slot), in which case admission
    reserves ceil((prompt + max_new) / page_size) pages up front so a
    running request can never run out mid-flight; short requests then
    share the budget that one max-length request would hog. `free`
    releases both the slot and its pages the moment a request finishes
    -- the scheduler admits from the queue on the same step.
    `defrag` compacts live slots into a dense prefix (a permutation),
    which keeps the active region contiguous for schedulers that lower
    several decode batch sizes.

  * jit-friendly state surgery -- `insert_slots` scatters the prefill
    states of a whole admission burst into their slot rows at once
    (with dropped padding rows, so one jitted prefill seats many
    requests); `permute_slots` applies a defrag permutation. Both
    locate the batch axis of every leaf from `api.state_axes(cfg)`, so
    they work for any family whose state the scheduler supports.
"""

from __future__ import annotations

import collections
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api


# ---------------------------------------------------------------------------
# page/slot accounting
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SlotInfo:
    owner: object            # request uid
    pages: int               # pages reserved
    tokens: int = 0          # tokens actually written (metrics only)


class PagePool:
    """Fixed-capacity slot + page accounting for the decode state.

    num_slots: rows in the slot array (the decode batch dimension).
    page_size: tokens per page.
    pages_per_slot: pages a single slot's cache row can hold; the cache
      capacity in tokens is page_size * pages_per_slot.
    total_pages: global page budget; defaults to the uncommitted
      num_slots * pages_per_slot, set it lower to model memory pressure.
    """

    def __init__(self, num_slots: int, page_size: int = 16,
                 pages_per_slot: int = 8, total_pages: int | None = None):
        assert num_slots > 0 and page_size > 0 and pages_per_slot > 0
        self.num_slots = num_slots
        self.page_size = page_size
        self.pages_per_slot = pages_per_slot
        self.total_pages = (num_slots * pages_per_slot
                            if total_pages is None else total_pages)
        self._slots: dict[int, SlotInfo] = {}

    # -- capacity ----------------------------------------------------------

    @property
    def slot_capacity(self) -> int:
        """Token capacity of one slot (the cache max_len to allocate)."""
        return self.page_size * self.pages_per_slot

    @property
    def used_pages(self) -> int:
        return sum(s.pages for s in self._slots.values())

    @property
    def free_pages(self) -> int:
        return self.total_pages - self.used_pages

    @property
    def active_slots(self) -> list[int]:
        return sorted(self._slots)

    @property
    def free_slots(self) -> list[int]:
        return [i for i in range(self.num_slots) if i not in self._slots]

    def owner(self, slot: int):
        return self._slots[slot].owner

    def pages_for(self, n_tokens: int) -> int:
        return max(1, math.ceil(n_tokens / self.page_size))

    # -- allocate / grow / free -------------------------------------------

    def can_admit(self, n_tokens: int) -> bool:
        pages = self.pages_for(n_tokens)
        return (len(self._slots) < self.num_slots
                and pages <= self.pages_per_slot
                and pages <= self.free_pages)

    def allocate(self, owner, n_tokens: int) -> int | None:
        """Reserve a slot + pages covering n_tokens total (prompt +
        planned generation). Returns the slot id, or None if the request
        does not fit right now (queue it) or can never fit (caller must
        reject: pages_for(n) > pages_per_slot)."""
        if not self.can_admit(n_tokens):
            return None
        slot = min(i for i in range(self.num_slots) if i not in self._slots)
        self._slots[slot] = SlotInfo(owner=owner, pages=self.pages_for(n_tokens))
        return slot

    def grow(self, slot: int, n_tokens: int):
        """Record actual token usage (reservation already covers it)."""
        info = self._slots[slot]
        info.tokens = n_tokens
        assert n_tokens <= info.pages * self.page_size, (
            f"slot {slot} wrote {n_tokens} tokens past its "
            f"{info.pages}-page reservation")

    def free(self, slot: int):
        """Release a finished request's slot and pages mid-flight."""
        del self._slots[slot]

    # -- defrag ------------------------------------------------------------

    def defrag(self) -> tuple[list[int], dict[int, int]]:
        """Compact live slots into a dense prefix.

        Returns (perm, moves): `perm` is a length-num_slots gather index
        list for `permute_slots` (new_state[i] = old_state[perm[i]]);
        `moves` maps old slot id -> new slot id for every live slot so
        the scheduler can remap request bookkeeping.
        """
        live = self.active_slots
        dead = [i for i in range(self.num_slots) if i not in self._slots]
        perm = live + dead
        moves = {old: new for new, old in enumerate(live)}
        self._slots = {moves[old]: info for old, info in self._slots.items()}
        return perm, moves


# ---------------------------------------------------------------------------
# slot-wise state surgery
# ---------------------------------------------------------------------------


def state_batch_axes(cfg) -> list[int]:
    """Flattened per-leaf index of the 'batch' (slot) axis of the decode
    state, in tree_flatten leaf order."""
    axes_leaves = jax.tree_util.tree_flatten(
        api.state_axes(cfg), is_leaf=lambda x: isinstance(x, tuple))[0]
    return [ax.index("batch") for ax in axes_leaves]


def state_seq_axes(cfg) -> list[int | None]:
    """Flattened per-leaf index of the 'kv_seq' (cache position) axis of
    the decode state, None for leaves without one (recurrent state), in
    tree_flatten leaf order."""
    axes_leaves = jax.tree_util.tree_flatten(
        api.state_axes(cfg), is_leaf=lambda x: isinstance(x, tuple))[0]
    return [ax.index("kv_seq") if "kv_seq" in ax else None
            for ax in axes_leaves]


def rollback_slots(state, pos, batch_axes: list[int],
                   seq_axes: list[int | None]):
    """Zero every cache entry at position >= pos[slot], per slot.

    The rewind step of speculative decoding: after a verify step writes
    k+1 draft KV rows and only m <= k are accepted, the rows past the
    accepted prefix are stale. `pos` is (B,) int32 -- each slot's count
    of VALID tokens (its next write index); entries at kv_seq index >=
    pos[b] are cleared, leaves without a kv_seq axis pass through.
    `batch_axes`/`seq_axes` come from `state_batch_axes(cfg)` /
    `state_seq_axes(cfg)` (static).
    """
    pos = jnp.asarray(pos, jnp.int32)
    leaves, treedef = jax.tree_util.tree_flatten(state)
    assert len(leaves) == len(batch_axes) == len(seq_axes)
    out = []
    for leaf, b, s in zip(leaves, batch_axes, seq_axes):
        if s is None:
            out.append(leaf)
            continue
        keep = jnp.arange(leaf.shape[s])[None, :] < pos[:, None]   # (B, S)
        shape = [1] * leaf.ndim
        shape[b], shape[s] = leaf.shape[b], leaf.shape[s]
        mask = (keep if b < s else keep.T).reshape(shape)
        out.append(jnp.where(mask, leaf, jnp.zeros((), leaf.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out)


def insert_slots(state, slot_state, slots, batch_axes: list[int],
                 shardings=None):
    """Scatter a batch-m prefill state into rows `slots` of the slot array.

    One call seats a whole admission burst. `slots` is (m,) int32 and
    may be traced; rows whose slot id falls outside the array (the
    scheduler pads bursts to a static bucket with id == num_slots) are
    DROPPED by the scatter, so padding never touches a live slot.
    `batch_axes` comes from `state_batch_axes(cfg)` (static).

    `shardings` (a NamedSharding tree matching `state`, from the
    scheduler's mesh placement) pins each scattered leaf back to the
    slot array's sharding: the scatter indexes the batch axis -- which
    is sharded over 'data' on a serving mesh -- with traced slot ids,
    and without the constraint GSPMD is free to resolve the update by
    replicating the multi-megabyte KV buffers. Constraining the output
    keeps the row writes shard-local (each 'data' shard masks the rows
    it owns) and keeps the donated buffer's layout stable across steps.
    """
    slots = jnp.asarray(slots, jnp.int32)
    leaves, treedef = jax.tree_util.tree_flatten(state)
    new_leaves = jax.tree_util.tree_flatten(slot_state)[0]
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    assert len(leaves) == len(new_leaves) == len(batch_axes) == len(shard_leaves)
    out = []
    for leaf, new, b, sh in zip(leaves, new_leaves, batch_axes, shard_leaves):
        # scatter directly on the batch axis (no transposes: with the
        # state buffer donated, this lowers to an in-place row write)
        idx = (slice(None),) * b + (slots,)
        upd = leaf.at[idx].set(new.astype(leaf.dtype), mode="drop")
        if sh is not None:
            upd = jax.lax.with_sharding_constraint(upd, sh)
        out.append(upd)
    return jax.tree_util.tree_unflatten(treedef, out)


def permute_slots(state, perm, batch_axes: list[int]):
    """Gather slot rows: new_state[i] = state[perm[i]] along each leaf's
    batch axis (defrag compaction)."""
    perm = jnp.asarray(perm, jnp.int32)
    leaves, treedef = jax.tree_util.tree_flatten(state)
    out = [jnp.take(leaf, perm, axis=b)
           for leaf, b in zip(leaves, batch_axes)]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# paged KV: physical pages, prefix sharing, copy-on-write
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KVCacheConfig:
    """Paged-KV serving options (`--kv-bits` / `--kv-page-size` /
    `--prefix-cache`).

    kv_bits: None or "fp" -> full-precision pages (token-identical to
      the dense slot path); 8 / 4 / 2 -> int8 code pages attended
      through the r-bit Matryoshka MSB slice; "auto" -> int8 pages
      whose attend width follows the router's weight representation
      (8 -> 8, 4 -> 4, mix'n'match -> 4, 2 -> 2).
    page_size: tokens per physical page (None -> ServeConfig.page_size).
    prefix_cache: hash prompt-prefix pages and share them read-only
      across requests (refcounts + copy-on-write on first divergence).
    attn_kernel: paged decode attend path -- "fused" (default) runs the
      Pallas kernel straight off the page store (in-tile unpack/slice/
      FMA + online softmax), "gather" the materialize-then-attend
      fallback. Engine-static: it never joins the step-closure key.
    """

    kv_bits: object = None
    page_size: int | None = None
    prefix_cache: bool = False
    attn_kernel: str = "fused"

    def __post_init__(self):
        if self.kv_bits not in (None, "fp", 2, 4, 8, "auto"):
            raise ValueError(
                f"kv_bits must be None/'fp'/8/4/2/'auto', got {self.kv_bits!r}")
        if self.attn_kernel not in ("fused", "gather"):
            raise ValueError(
                f"attn_kernel must be 'fused' or 'gather', got "
                f"{self.attn_kernel!r}")

    @property
    def quantized(self) -> bool:
        return self.kv_bits not in (None, "fp")

    def attend_bits(self, rep_key=None) -> int | None:
        """Static attend bitwidth for one step closure (None = fp)."""
        if not self.quantized:
            return None
        if self.kv_bits != "auto":
            return int(self.kv_bits)
        return kv_bits_for_rep(rep_key)

    def bytes_per_token(self, cfg) -> int:
        """KV bytes one attend step READS per cached token: k + v rows
        across layers at the sliced attend width (codes + fp32
        scale/offset), or the full-precision row in fp mode. Headline
        number of the metrics `kv` section; `bytes_read_per_token` is
        the same accounting parameterized by representation key, and
        `resident_bytes_per_token` the width-independent storage cost."""
        kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        L = cfg.num_layers
        if not self.quantized:
            itemsize = 2 if cfg.param_dtype == "bfloat16" else 4
            return 2 * L * kh * hd * itemsize
        bits = 8 if self.kv_bits == "auto" else int(self.kv_bits)
        return 2 * L * kh * (hd * bits // 8 + 8)

    def resident_bytes_per_token(self, cfg) -> int:
        """KV bytes one cached token OCCUPIES in the page store.

        Quantized mode always stores the full 8-bit parent codes plus
        the per-(row, head) fp32 alpha/beta -- the Matryoshka contract:
        every attend width reads the SAME bytes, so residency is
        attend-width-independent. fp mode has no code/scale split."""
        kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        L = cfg.num_layers
        if not self.quantized:
            itemsize = 2 if cfg.param_dtype == "bfloat16" else 4
            return 2 * L * kh * hd * itemsize
        return 2 * L * kh * (hd + 8)

    def bytes_read_per_token(self, cfg, rep_key=None) -> int:
        """Analytic KV bytes one attend step consumes per cached token
        at the attend width of `rep_key` (the fused kernel's payload:
        r-bit sliced codes + fp32 scale/offset). Strictly decreasing in
        the attend width 8 > 4 > 2 while residency stays constant --
        the byte saving the in-tile slice actually banks."""
        kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        L = cfg.num_layers
        bits = self.attend_bits(rep_key)
        if bits is None:
            itemsize = 2 if cfg.param_dtype == "bfloat16" else 4
            return 2 * L * kh * hd * itemsize
        return 2 * L * kh * (hd * bits // 8 + 8)


def kv_bits_for_rep(rep_key) -> int:
    """Router-coupled KV attend width for one weight representation key
    (see scheduler._step_fns): uniform int tiers keep their width,
    per-layer Mix'n'Match tuples attend at 4, extra-precision wrappers
    follow their base key, dequantized (None) reads the full int8."""
    if (isinstance(rep_key, tuple) and len(rep_key) == 2
            and rep_key[1] == "ep"):
        return kv_bits_for_rep(rep_key[0])
    if isinstance(rep_key, tuple):
        return 4
    if rep_key in (2, 4, 8):
        return int(rep_key)
    return 8


@dataclasses.dataclass
class _PrefixEntry:
    """One radix-index node: a physical page holding `tokens` (page
    rows) reachable from `parent`. Holds one refcount on its page."""

    key: object
    page: int
    tokens: tuple
    parent: object           # key of the parent entry, or None (root)
    full: bool               # full page (immutable) vs partial tail
    children: int = 0
    tick: int = 0


class PagedPool(PagePool):
    """PagePool with PHYSICAL page identities and prefix sharing.

    Extends the accounting base with a free list of page ids, per-page
    refcounts, per-slot page lists (the host side of the device page
    table), and -- with `prefix_cache` -- a radix index over prompt-
    prefix pages: admission walks the index page-by-page (chained full
    pages, then a longest-common-prefix partial tail), hits acquire the
    matched pages read-only, and a hit whose shared length ends inside
    a page schedules a copy-on-write so the divergent suffix never
    touches the shared original. Index entries hold their own refcount
    and are evicted LRU (childless first) when allocation runs dry.
    """

    def __init__(self, num_slots: int, page_size: int = 16,
                 pages_per_slot: int = 8, total_pages: int | None = None,
                 prefix_cache: bool = False):
        super().__init__(num_slots, page_size,
                         pages_per_slot=pages_per_slot,
                         total_pages=total_pages)
        self.prefix_cache = prefix_cache
        self._free = collections.deque(range(self.total_pages))
        self._refs = [0] * self.total_pages
        self.slot_pages: dict[int, list[int]] = {}
        self.slot_shared: dict[int, int] = {}     # leading read-only pages
        self._prefix: dict[object, _PrefixEntry] = {}
        self._tick = 0
        self.prefix_lookups = 0
        self.prefix_hits = 0
        self.prefix_shared_tokens = 0

    # physical page accounting replaces the base's per-slot sum (shared
    # pages are counted once, not once per holder)
    @property
    def used_pages(self) -> int:
        return self.total_pages - len(self._free)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def written_pages(self) -> int:
        """Pages holding at least one written KV row (vs merely
        reserved): slot pages up to the slot's token watermark, plus
        every prefix-index page."""
        seen = set()
        for slot, info in self._slots.items():
            pages = self.slot_pages.get(slot, [])
            n = (min(len(pages), math.ceil(info.tokens / self.page_size))
                 if info.tokens else 0)
            seen.update(pages[:n])
        seen.update(e.page for e in self._prefix.values())
        return len(seen)

    def _bump(self) -> int:
        self._tick += 1
        return self._tick

    def _release(self, pid: int):
        self._refs[pid] -= 1
        assert self._refs[pid] >= 0, f"page {pid} over-released"
        if self._refs[pid] == 0:
            self._free.append(pid)

    def _evict_one(self) -> bool:
        """Drop the LRU childless prefix entry; True if one was dropped."""
        victim = None
        for e in self._prefix.values():
            if e.children == 0 and (victim is None or e.tick < victim.tick):
                victim = e
        if victim is None:
            return False
        del self._prefix[victim.key]
        if victim.parent is not None:
            self._prefix[victim.parent].children -= 1
        self._release(victim.page)
        return True

    def _take_pages(self, n: int) -> list[int] | None:
        """Allocate n fresh pages (evicting prefix entries if needed);
        None -- with nothing taken -- if the pool cannot cover them."""
        while len(self._free) < n:
            if not self._evict_one():
                return None
        out = []
        for _ in range(n):
            pid = self._free.popleft()
            self._refs[pid] = 1
            out.append(pid)
        return out

    # -- admission with prefix matching ------------------------------------

    def _match_prefix(self, prompt) -> tuple[int, list[int]]:
        """Longest indexed prefix of `prompt` in whole pages plus a
        partial tail, capped one token short of the full prompt (the
        suffix prefill must emit first-token logits)."""
        limit = len(prompt) - 1
        T = self.page_size
        pages: list[int] = []
        key, s = None, 0
        while s + T <= limit:
            k = ("page", key, tuple(prompt[s:s + T]))
            e = self._prefix.get(k)
            if e is None:
                break
            e.tick = self._bump()
            pages.append(e.page)
            key = k
            s += T
        if s < limit:
            e = self._prefix.get(("tail", key))
            if e is not None:
                m = 0
                for a, b in zip(e.tokens, prompt[s:limit]):
                    if a != b:
                        break
                    m += 1
                if m > 0:
                    e.tick = self._bump()
                    pages.append(e.page)
                    s += m
        return s, pages

    def admit(self, owner, prompt, n_tokens: int):
        """Seat a request: reserve a slot and pages_for(n_tokens) pages,
        reusing indexed prefix pages read-only where the prompt matches.

        Returns (slot, shared_len, cow) -- `cow` a list of (src, dst)
        page copies the caller must apply (device-side) before the
        suffix prefill writes into its first divergent page -- or None
        if no slot / not enough pages right now.
        """
        if len(self._slots) >= self.num_slots:
            return None
        need = self.pages_for(n_tokens)
        if need > self.pages_per_slot:
            return None
        prompt = [int(t) for t in prompt]
        shared_len, shared_pages = ((0, [])
                                    if not self.prefix_cache
                                    else self._match_prefix(prompt))
        T = self.page_size
        n_full = shared_len // T          # whole pages shared read-only
        fresh = self._take_pages(need - n_full)
        if fresh is None:
            return None
        pages = []
        for pid in shared_pages[:n_full]:
            self._refs[pid] += 1
            pages.append(pid)
        cow = []
        if shared_len % T:
            # shared length ends inside a page: the suffix's first write
            # would land in the shared original -- copy it first
            cow.append((shared_pages[-1], fresh[0]))
        pages += fresh
        slot = min(i for i in range(self.num_slots) if i not in self._slots)
        self._slots[slot] = SlotInfo(owner=owner, pages=len(pages))
        self.slot_pages[slot] = pages
        self.slot_shared[slot] = n_full
        if self.prefix_cache:
            self.prefix_lookups += 1
            if shared_len:
                self.prefix_hits += 1
                self.prefix_shared_tokens += shared_len
        return slot, shared_len, cow

    def allocate(self, owner, n_tokens: int) -> int | None:
        """Base-compatible admission (no prompt, no prefix matching)."""
        got = self.admit(owner, (), n_tokens)
        return None if got is None else got[0]

    def register_prefix(self, slot: int, prompt):
        """Index `slot`'s freshly prefilled prompt pages for reuse:
        chained full pages plus the partial tail (longest tail wins)."""
        if not self.prefix_cache:
            return
        prompt = [int(t) for t in prompt]
        T = self.page_size
        pages = self.slot_pages[slot]
        key, s = None, 0
        while s + T <= len(prompt):
            k = ("page", key, tuple(prompt[s:s + T]))
            if k not in self._prefix:
                pid = pages[s // T]
                self._refs[pid] += 1
                self._prefix[k] = _PrefixEntry(
                    key=k, page=pid, tokens=tuple(prompt[s:s + T]),
                    parent=key, full=True, tick=self._bump())
                if key is not None:
                    self._prefix[key].children += 1
            key = k
            s += T
        tail = tuple(prompt[s:])
        if not tail:
            return
        k = ("tail", key)
        e = self._prefix.get(k)
        pid = pages[s // T]
        if e is None:
            self._refs[pid] += 1
            self._prefix[k] = _PrefixEntry(
                key=k, page=pid, tokens=tail, parent=key, full=False,
                tick=self._bump())
            if key is not None:
                self._prefix[key].children += 1
        elif len(tail) > len(e.tokens):
            self._refs[pid] += 1
            self._release(e.page)
            e.page, e.tokens, e.tick = pid, tail, self._bump()

    # -- lifecycle ----------------------------------------------------------

    def grow(self, slot: int, n_tokens: int):
        """Record token usage (admission reserved every page up front)."""
        info = self._slots[slot]
        info.tokens = n_tokens
        assert n_tokens <= len(self.slot_pages[slot]) * self.page_size, (
            f"slot {slot} wrote {n_tokens} tokens past its "
            f"{len(self.slot_pages[slot])}-page reservation")

    def free(self, slot: int):
        for pid in self.slot_pages.pop(slot):
            self._release(pid)
        self.slot_shared.pop(slot, None)
        del self._slots[slot]

    def defrag(self) -> tuple[list[int], dict[int, int]]:
        """Compact live slots into a dense prefix. Paged defrag is pure
        HOST bookkeeping: only slot ids move; physical pages (and the
        device page store) stay put -- the caller rebuilds its page
        table from `page_table()`."""
        perm, moves = super().defrag()
        self.slot_pages = {moves[o]: v for o, v in self.slot_pages.items()}
        self.slot_shared = {moves[o]: v for o, v in self.slot_shared.items()}
        return perm, moves

    def page_table(self) -> np.ndarray:
        """(num_slots, pages_per_slot) int32 physical page ids; holes
        carry the sentinel `total_pages` (dropped by scatters, zero-
        filled by gathers)."""
        tab = np.full((self.num_slots, self.pages_per_slot),
                      self.total_pages, np.int32)
        for slot, pages in self.slot_pages.items():
            tab[slot, :len(pages)] = pages
        return tab


def copy_pages(state, src, dst):
    """Device-side page copy (the COW step of prefix sharing).

    src/dst: (n,) int32 page ids; every paged leaf (layer, page, ...)
    copies rows src -> dst along its page axis. Sentinel ids (==
    num_pages) are dropped by the scatter, so callers can pad the copy
    list to a static bucket length.
    """
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)

    def cp(leaf):
        return leaf.at[:, dst].set(
            jnp.take(leaf, src, axis=1, mode="clip"), mode="drop")

    return jax.tree.map(cp, state)
