"""Slot/page pool over the model decode state (continuous batching).

The decode state produced by `api.init_state(cfg, num_slots, capacity)`
is a fixed-shape pytree whose "batch" axis is a SLOT ARRAY: row i holds
the KV cache (and/or recurrent state) of whatever request currently owns
slot i. Static shapes keep a single jitted decode step alive for the
whole serving session; requests come and go by overwriting rows.

Two layers live here:

  * `PagePool` -- pure-Python accounting. Slots are the unit of
    occupancy (one request per slot); pages (page_size tokens each) are
    the unit of memory budget. The pool may be *overcommitted*
    (total_pages < num_slots * pages_per_slot), in which case admission
    reserves ceil((prompt + max_new) / page_size) pages up front so a
    running request can never run out mid-flight; short requests then
    share the budget that one max-length request would hog. `free`
    releases both the slot and its pages the moment a request finishes
    -- the scheduler admits from the queue on the same step.
    `defrag` compacts live slots into a dense prefix (a permutation),
    which keeps the active region contiguous for schedulers that lower
    several decode batch sizes.

  * jit-friendly state surgery -- `insert_slots` scatters the prefill
    states of a whole admission burst into their slot rows at once
    (with dropped padding rows, so one jitted prefill seats many
    requests); `permute_slots` applies a defrag permutation. Both
    locate the batch axis of every leaf from `api.state_axes(cfg)`, so
    they work for any family whose state the scheduler supports.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models import api


# ---------------------------------------------------------------------------
# page/slot accounting
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SlotInfo:
    owner: object            # request uid
    pages: int               # pages reserved
    tokens: int = 0          # tokens actually written (metrics only)


class PagePool:
    """Fixed-capacity slot + page accounting for the decode state.

    num_slots: rows in the slot array (the decode batch dimension).
    page_size: tokens per page.
    pages_per_slot: pages a single slot's cache row can hold; the cache
      capacity in tokens is page_size * pages_per_slot.
    total_pages: global page budget; defaults to the uncommitted
      num_slots * pages_per_slot, set it lower to model memory pressure.
    """

    def __init__(self, num_slots: int, page_size: int = 16,
                 pages_per_slot: int = 8, total_pages: int | None = None):
        assert num_slots > 0 and page_size > 0 and pages_per_slot > 0
        self.num_slots = num_slots
        self.page_size = page_size
        self.pages_per_slot = pages_per_slot
        self.total_pages = (num_slots * pages_per_slot
                            if total_pages is None else total_pages)
        self._slots: dict[int, SlotInfo] = {}

    # -- capacity ----------------------------------------------------------

    @property
    def slot_capacity(self) -> int:
        """Token capacity of one slot (the cache max_len to allocate)."""
        return self.page_size * self.pages_per_slot

    @property
    def used_pages(self) -> int:
        return sum(s.pages for s in self._slots.values())

    @property
    def free_pages(self) -> int:
        return self.total_pages - self.used_pages

    @property
    def active_slots(self) -> list[int]:
        return sorted(self._slots)

    @property
    def free_slots(self) -> list[int]:
        return [i for i in range(self.num_slots) if i not in self._slots]

    def owner(self, slot: int):
        return self._slots[slot].owner

    def pages_for(self, n_tokens: int) -> int:
        return max(1, math.ceil(n_tokens / self.page_size))

    # -- allocate / grow / free -------------------------------------------

    def can_admit(self, n_tokens: int) -> bool:
        pages = self.pages_for(n_tokens)
        return (len(self._slots) < self.num_slots
                and pages <= self.pages_per_slot
                and pages <= self.free_pages)

    def allocate(self, owner, n_tokens: int) -> int | None:
        """Reserve a slot + pages covering n_tokens total (prompt +
        planned generation). Returns the slot id, or None if the request
        does not fit right now (queue it) or can never fit (caller must
        reject: pages_for(n) > pages_per_slot)."""
        if not self.can_admit(n_tokens):
            return None
        slot = min(i for i in range(self.num_slots) if i not in self._slots)
        self._slots[slot] = SlotInfo(owner=owner, pages=self.pages_for(n_tokens))
        return slot

    def grow(self, slot: int, n_tokens: int):
        """Record actual token usage (reservation already covers it)."""
        info = self._slots[slot]
        info.tokens = n_tokens
        assert n_tokens <= info.pages * self.page_size, (
            f"slot {slot} wrote {n_tokens} tokens past its "
            f"{info.pages}-page reservation")

    def free(self, slot: int):
        """Release a finished request's slot and pages mid-flight."""
        del self._slots[slot]

    # -- defrag ------------------------------------------------------------

    def defrag(self) -> tuple[list[int], dict[int, int]]:
        """Compact live slots into a dense prefix.

        Returns (perm, moves): `perm` is a length-num_slots gather index
        list for `permute_slots` (new_state[i] = old_state[perm[i]]);
        `moves` maps old slot id -> new slot id for every live slot so
        the scheduler can remap request bookkeeping.
        """
        live = self.active_slots
        dead = [i for i in range(self.num_slots) if i not in self._slots]
        perm = live + dead
        moves = {old: new for new, old in enumerate(live)}
        self._slots = {moves[old]: info for old, info in self._slots.items()}
        return perm, moves


# ---------------------------------------------------------------------------
# slot-wise state surgery
# ---------------------------------------------------------------------------


def state_batch_axes(cfg) -> list[int]:
    """Flattened per-leaf index of the 'batch' (slot) axis of the decode
    state, in tree_flatten leaf order."""
    axes_leaves = jax.tree_util.tree_flatten(
        api.state_axes(cfg), is_leaf=lambda x: isinstance(x, tuple))[0]
    return [ax.index("batch") for ax in axes_leaves]


def state_seq_axes(cfg) -> list[int | None]:
    """Flattened per-leaf index of the 'kv_seq' (cache position) axis of
    the decode state, None for leaves without one (recurrent state), in
    tree_flatten leaf order."""
    axes_leaves = jax.tree_util.tree_flatten(
        api.state_axes(cfg), is_leaf=lambda x: isinstance(x, tuple))[0]
    return [ax.index("kv_seq") if "kv_seq" in ax else None
            for ax in axes_leaves]


def rollback_slots(state, pos, batch_axes: list[int],
                   seq_axes: list[int | None]):
    """Zero every cache entry at position >= pos[slot], per slot.

    The rewind step of speculative decoding: after a verify step writes
    k+1 draft KV rows and only m <= k are accepted, the rows past the
    accepted prefix are stale. `pos` is (B,) int32 -- each slot's count
    of VALID tokens (its next write index); entries at kv_seq index >=
    pos[b] are cleared, leaves without a kv_seq axis pass through.
    `batch_axes`/`seq_axes` come from `state_batch_axes(cfg)` /
    `state_seq_axes(cfg)` (static).
    """
    pos = jnp.asarray(pos, jnp.int32)
    leaves, treedef = jax.tree_util.tree_flatten(state)
    assert len(leaves) == len(batch_axes) == len(seq_axes)
    out = []
    for leaf, b, s in zip(leaves, batch_axes, seq_axes):
        if s is None:
            out.append(leaf)
            continue
        keep = jnp.arange(leaf.shape[s])[None, :] < pos[:, None]   # (B, S)
        shape = [1] * leaf.ndim
        shape[b], shape[s] = leaf.shape[b], leaf.shape[s]
        mask = (keep if b < s else keep.T).reshape(shape)
        out.append(jnp.where(mask, leaf, jnp.zeros((), leaf.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out)


def insert_slots(state, slot_state, slots, batch_axes: list[int],
                 shardings=None):
    """Scatter a batch-m prefill state into rows `slots` of the slot array.

    One call seats a whole admission burst. `slots` is (m,) int32 and
    may be traced; rows whose slot id falls outside the array (the
    scheduler pads bursts to a static bucket with id == num_slots) are
    DROPPED by the scatter, so padding never touches a live slot.
    `batch_axes` comes from `state_batch_axes(cfg)` (static).

    `shardings` (a NamedSharding tree matching `state`, from the
    scheduler's mesh placement) pins each scattered leaf back to the
    slot array's sharding: the scatter indexes the batch axis -- which
    is sharded over 'data' on a serving mesh -- with traced slot ids,
    and without the constraint GSPMD is free to resolve the update by
    replicating the multi-megabyte KV buffers. Constraining the output
    keeps the row writes shard-local (each 'data' shard masks the rows
    it owns) and keeps the donated buffer's layout stable across steps.
    """
    slots = jnp.asarray(slots, jnp.int32)
    leaves, treedef = jax.tree_util.tree_flatten(state)
    new_leaves = jax.tree_util.tree_flatten(slot_state)[0]
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    assert len(leaves) == len(new_leaves) == len(batch_axes) == len(shard_leaves)
    out = []
    for leaf, new, b, sh in zip(leaves, new_leaves, batch_axes, shard_leaves):
        # scatter directly on the batch axis (no transposes: with the
        # state buffer donated, this lowers to an in-place row write)
        idx = (slice(None),) * b + (slots,)
        upd = leaf.at[idx].set(new.astype(leaf.dtype), mode="drop")
        if sh is not None:
            upd = jax.lax.with_sharding_constraint(upd, sh)
        out.append(upd)
    return jax.tree_util.tree_unflatten(treedef, out)


def permute_slots(state, perm, batch_axes: list[int]):
    """Gather slot rows: new_state[i] = state[perm[i]] along each leaf's
    batch axis (defrag compaction)."""
    perm = jnp.asarray(perm, jnp.int32)
    leaves, treedef = jax.tree_util.tree_flatten(state)
    out = [jnp.take(leaf, perm, axis=b)
           for leaf, b in zip(leaves, batch_axes)]
    return jax.tree_util.tree_unflatten(treedef, out)
