"""Serving metrics: per-request latency/TTFT and per-step tier counters.

The scheduler feeds this with explicit timestamps (a `clock()` float,
wall time in the live driver, a virtual clock in tests), so the module
is deterministic under test. `summary()` flattens everything into a
plain dict of floats/ints that the benchmarks serialize as
BENCH_serve.json.
"""

from __future__ import annotations

import dataclasses


def _percentile(values: list[float], q: float) -> float:
    """Linear-interpolation percentile (numpy's default), q in [0, 100]."""
    if not values:
        return 0.0
    vs = sorted(values)
    if len(vs) == 1:
        return float(vs[0])
    rank = (len(vs) - 1) * q / 100.0
    lo = int(rank)
    hi = min(lo + 1, len(vs) - 1)
    return float(vs[lo] + (vs[hi] - vs[lo]) * (rank - lo))


@dataclasses.dataclass
class RequestRecord:
    uid: object
    arrival: float
    admitted: float | None = None
    first_token: float | None = None
    finished: float | None = None
    prompt_tokens: int = 0
    generated_tokens: int = 0
    admit_tier: str = ""

    @property
    def ttft(self) -> float | None:
        if self.first_token is None:
            return None
        return self.first_token - self.arrival

    @property
    def latency(self) -> float | None:
        if self.finished is None:
            return None
        return self.finished - self.arrival


class ServeMetrics:
    """Aggregates the continuous-batching scheduler's counters."""

    def __init__(self):
        self.requests: dict[object, RequestRecord] = {}
        self.steps = 0
        self.tier_steps: dict[str, int] = {}
        self.tier_tokens: dict[str, int] = {}
        self.queue_depth_samples: list[int] = []
        self.active_samples: list[int] = []
        self.tier_switches = 0
        self.tier_weight_bytes: dict[str, dict] = {}
        self._last_tier: str | None = None
        self.tier_decoded_tokens: dict[str, int] = {}
        # speculative decoding: one "round" is one slot's draft block
        # going through one verify step (so spec_rounds == per-slot
        # verify-model steps)
        self.spec_rounds = 0
        self.spec_drafted = 0
        self.spec_accepted = 0
        self.spec_emitted = 0
        self.spec_tier_rounds: dict[str, int] = {}

    # -- request lifecycle -------------------------------------------------

    def on_submit(self, uid, now: float, prompt_tokens: int):
        self.requests[uid] = RequestRecord(
            uid=uid, arrival=now, prompt_tokens=prompt_tokens)

    def on_admit(self, uid, now: float, tier: str):
        rec = self.requests[uid]
        rec.admitted = now
        rec.admit_tier = tier

    def on_first_token(self, uid, now: float):
        rec = self.requests[uid]
        if rec.first_token is None:
            rec.first_token = now

    def on_finish(self, uid, now: float, generated_tokens: int):
        rec = self.requests[uid]
        rec.finished = now
        rec.generated_tokens = generated_tokens

    def on_tier_bytes(self, tier: str, *, packed_bits, packed_nbytes: int,
                      weight_nbytes: int, effective_bits: float = 0.0,
                      per_device_plane_nbytes: int = 0):
        """Record the measured HBM weight footprint of a served tier
        (fed by the scheduler on every tier activation, so the
        downgrade -> fewer-weight-bytes claim is a reported number).
        `effective_bits` is the Table 7 accounting of the served planes
        (base bits + overflow fraction for extra-precision tiers);
        `per_device_plane_nbytes` is the largest single-device shard of
        the plane bytes (== packed_nbytes / model_parallel on a TP
        serving mesh, == packed_nbytes off-mesh or when 0 is fed)."""
        self.tier_weight_bytes[tier] = {
            "packed_bits": packed_bits,
            "packed_nbytes": int(packed_nbytes),
            "weight_nbytes": int(weight_nbytes),
            "effective_bits": float(effective_bits),
            "per_device_plane_nbytes": int(per_device_plane_nbytes
                                           or packed_nbytes),
        }

    # -- per-step counters -------------------------------------------------

    def on_step(self, tier: str, *, new_tokens: int, active: int,
                queue_depth: int, decoded_tokens: int = 0):
        self.steps += 1
        self.tier_steps[tier] = self.tier_steps.get(tier, 0) + 1
        self.tier_tokens[tier] = self.tier_tokens.get(tier, 0) + new_tokens
        self.tier_decoded_tokens[tier] = (
            self.tier_decoded_tokens.get(tier, 0) + decoded_tokens)
        self.queue_depth_samples.append(queue_depth)
        self.active_samples.append(active)
        if self._last_tier is not None and tier != self._last_tier:
            self.tier_switches += 1
        self._last_tier = tier

    def on_spec_round(self, tier: str, *, drafted: int, accepted: int,
                      emitted: int):
        """One slot's draft/verify round: `drafted` = k draft tokens,
        `accepted` = the agreeing prefix length m in [0, k], `emitted`
        = tokens actually appended (m + 1 bonus, truncated at
        max_new_tokens / EOS)."""
        self.spec_rounds += 1
        self.spec_drafted += drafted
        self.spec_accepted += accepted
        self.spec_emitted += emitted
        self.spec_tier_rounds[tier] = self.spec_tier_rounds.get(tier, 0) + 1

    # -- aggregation -------------------------------------------------------

    def summary(self) -> dict:
        done = [r for r in self.requests.values() if r.finished is not None]
        ttfts = [r.ttft for r in done if r.ttft is not None]
        lats = [r.latency for r in done]
        gen = sum(r.generated_tokens for r in done)
        span = 0.0
        if done:
            t0 = min(r.arrival for r in done)
            t1 = max(r.finished for r in done)
            span = max(t1 - t0, 1e-9)
        total_steps = max(self.steps, 1)
        return {
            "requests_submitted": len(self.requests),
            "requests_completed": len(done),
            "generated_tokens": gen,
            "throughput_tok_s": gen / span if done else 0.0,
            "mean_ttft_s": sum(ttfts) / len(ttfts) if ttfts else 0.0,
            "p50_ttft_s": _percentile(ttfts, 50.0),
            "p95_ttft_s": _percentile(ttfts, 95.0),
            "max_ttft_s": max(ttfts) if ttfts else 0.0,
            "mean_latency_s": sum(lats) / len(lats) if lats else 0.0,
            "scheduler_steps": self.steps,
            "tier_switches": self.tier_switches,
            "mean_queue_depth": (sum(self.queue_depth_samples)
                                 / len(self.queue_depth_samples)
                                 if self.queue_depth_samples else 0.0),
            "max_queue_depth": max(self.queue_depth_samples, default=0),
            "mean_active_slots": (sum(self.active_samples)
                                  / len(self.active_samples)
                                  if self.active_samples else 0.0),
            "tier_occupancy": {t: n / total_steps
                               for t, n in sorted(self.tier_steps.items())},
            "tier_tokens": dict(sorted(self.tier_tokens.items())),
            "tier_decoded_tokens": dict(
                sorted(self.tier_decoded_tokens.items())),
            "tier_weight_bytes": dict(sorted(self.tier_weight_bytes.items())),
            "spec": self._spec_summary(),
        }

    def _spec_summary(self) -> dict:
        """Speculative-decoding acceptance bookkeeping (all zeros when
        spec decode is off). `verify_steps` counts per-slot verify
        evaluations; with any acceptance at all it sits strictly below
        `emitted_tokens` -- the speed multiplier the self-speculative
        path exists for."""
        return {
            "rounds": self.spec_rounds,
            "drafted_tokens": self.spec_drafted,
            "accepted_tokens": self.spec_accepted,
            "emitted_tokens": self.spec_emitted,
            "verify_steps": self.spec_rounds,
            "acceptance_rate": (self.spec_accepted / self.spec_drafted
                                if self.spec_drafted else 0.0),
            "mean_accepted_prefix_len": (self.spec_emitted / self.spec_rounds
                                         if self.spec_rounds else 0.0),
            "verify_steps_per_token": (self.spec_rounds / self.spec_emitted
                                       if self.spec_emitted else 0.0),
            "tier_rounds": dict(sorted(self.spec_tier_rounds.items())),
        }
