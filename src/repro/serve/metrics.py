"""Serving metrics: per-request latency/TTFT and per-step tier counters.

The scheduler feeds this with explicit timestamps (a `clock()` float,
wall time in the live driver, a virtual clock in tests), so the module
is deterministic under test. `summary()` flattens everything into a
plain dict of floats/ints that the benchmarks serialize as
BENCH_serve.json. `FleetMetrics` is the N-replica twin: per-replica
tier occupancy, requeue/failure counters, and the zero-request-loss
accounting the fleet benchmarks report (serve/fleet.py).
"""

from __future__ import annotations

import dataclasses


def _percentile(values: list[float], q: float) -> float:
    """Linear-interpolation percentile (numpy's default), q in [0, 100].

    Empty windows report 0.0 (a metric, not an error); a single sample
    IS every percentile of its window. q is clamped into [0, 100]: the
    unclamped rank formula extrapolates outside the sorted range for
    out-of-range q (int() truncates a negative rank toward zero, so
    q < 0 used to yield `vs[0] - eps * (vs[1] - vs[0])`, below the
    window minimum)."""
    if not values:
        return 0.0
    vs = sorted(values)
    if len(vs) == 1:
        return float(vs[0])
    q = min(max(float(q), 0.0), 100.0)
    rank = (len(vs) - 1) * q / 100.0
    lo = int(rank)
    hi = min(lo + 1, len(vs) - 1)
    return float(vs[lo] + (vs[hi] - vs[lo]) * (rank - lo))


@dataclasses.dataclass
class RequestRecord:
    uid: object
    arrival: float
    admitted: float | None = None
    first_token: float | None = None
    finished: float | None = None
    prompt_tokens: int = 0
    generated_tokens: int = 0
    admit_tier: str = ""
    shared_prefix_tokens: int = 0       # paged mode: prefix-cache reuse

    @property
    def ttft(self) -> float | None:
        if self.first_token is None:
            return None
        return self.first_token - self.arrival

    @property
    def latency(self) -> float | None:
        if self.finished is None:
            return None
        return self.finished - self.arrival


class ServeMetrics:
    """Aggregates the continuous-batching scheduler's counters."""

    def __init__(self):
        self.requests: dict[object, RequestRecord] = {}
        self.steps = 0
        self.tier_steps: dict[str, int] = {}
        self.tier_tokens: dict[str, int] = {}
        self.queue_depth_samples: list[int] = []
        self.active_samples: list[int] = []
        self.tier_switches = 0
        self.tier_weight_bytes: dict[str, dict] = {}
        self._last_tier: str | None = None
        self.tier_decoded_tokens: dict[str, int] = {}
        # speculative decoding: one "round" is one slot's draft block
        # going through one verify step (so spec_rounds == per-slot
        # verify-model steps)
        self.spec_rounds = 0
        self.spec_drafted = 0
        self.spec_accepted = 0
        self.spec_emitted = 0
        self.spec_tier_rounds: dict[str, int] = {}
        # paged KV cache: None until the scheduler runs in paged mode
        self.kv_config: dict | None = None
        self.page_reserved_samples: list[int] = []
        self.page_written_samples: list[int] = []
        self.page_total: int = 0

    # -- request lifecycle -------------------------------------------------

    def on_submit(self, uid, now: float, prompt_tokens: int):
        self.requests[uid] = RequestRecord(
            uid=uid, arrival=now, prompt_tokens=prompt_tokens)

    def on_admit(self, uid, now: float, tier: str):
        rec = self.requests[uid]
        rec.admitted = now
        rec.admit_tier = tier

    def on_first_token(self, uid, now: float):
        rec = self.requests[uid]
        if rec.first_token is None:
            rec.first_token = now

    def on_finish(self, uid, now: float, generated_tokens: int):
        rec = self.requests[uid]
        rec.finished = now
        rec.generated_tokens = generated_tokens

    def on_tier_bytes(self, tier: str, *, packed_bits, packed_nbytes: int,
                      weight_nbytes: int, effective_bits: float = 0.0,
                      per_device_plane_nbytes: int = 0):
        """Record the measured HBM weight footprint of a served tier
        (fed by the scheduler on every tier activation, so the
        downgrade -> fewer-weight-bytes claim is a reported number).
        `effective_bits` is the Table 7 accounting of the served planes
        (base bits + overflow fraction for extra-precision tiers);
        `per_device_plane_nbytes` is the largest single-device shard of
        the plane bytes (== packed_nbytes / model_parallel on a TP
        serving mesh, == packed_nbytes off-mesh or when 0 is fed)."""
        self.tier_weight_bytes[tier] = {
            "packed_bits": packed_bits,
            "packed_nbytes": int(packed_nbytes),
            "weight_nbytes": int(weight_nbytes),
            "effective_bits": float(effective_bits),
            "per_device_plane_nbytes": int(per_device_plane_nbytes
                                           or packed_nbytes),
        }

    # -- paged KV cache ----------------------------------------------------

    def on_kv_config(self, *, bytes_per_token: int, kv_bits, prefix_cache,
                     resident_bytes_per_token: int | None = None,
                     bytes_read_per_token: int | None = None,
                     attn_kernel: str | None = None):
        """Static paged-cache config (fed once at scheduler construction
        and after reset): the per-token KV footprint claims are computed
        numbers, not flag echoes. `resident_bytes_per_token` is what a
        cached token occupies (parent int8 codes + scales, attend-width
        independent); `bytes_read_per_token` the analytic per-step read
        payload at the attend width -- the number the fused kernel's
        in-tile slice shrinks while residency stays put."""
        self.kv_config = {
            "kv_bits": "fp" if kv_bits in (None, "fp") else kv_bits,
            "bytes_per_token": int(bytes_per_token),
            "prefix_cache": bool(prefix_cache),
        }
        if resident_bytes_per_token is not None:
            self.kv_config["resident_bytes_per_token"] = int(
                resident_bytes_per_token)
        if bytes_read_per_token is not None:
            self.kv_config["bytes_read_per_token"] = int(bytes_read_per_token)
        if attn_kernel is not None:
            self.kv_config["attn_kernel"] = str(attn_kernel)

    def on_admit_kv(self, uid, prompt_tokens: int, shared_tokens: int):
        """Per-admission prefix-cache outcome: `shared_tokens` prompt
        tokens were served from already-written shared pages (0 on a
        cold admission), so the hit/cold TTFT split is measurable."""
        self.requests[uid].shared_prefix_tokens = int(shared_tokens)

    def on_pages(self, reserved: int, written: int, total: int):
        """Page-pool occupancy snapshot after a working step: `reserved`
        counts pages held by live slots (including headroom not yet
        written), `written` only pages holding real KV rows -- the gap
        is the overcommit opportunity."""
        self.page_reserved_samples.append(int(reserved))
        self.page_written_samples.append(int(written))
        self.page_total = int(total)

    # -- per-step counters -------------------------------------------------

    def on_step(self, tier: str, *, new_tokens: int, active: int,
                queue_depth: int, decoded_tokens: int = 0):
        self.steps += 1
        self.tier_steps[tier] = self.tier_steps.get(tier, 0) + 1
        self.tier_tokens[tier] = self.tier_tokens.get(tier, 0) + new_tokens
        self.tier_decoded_tokens[tier] = (
            self.tier_decoded_tokens.get(tier, 0) + decoded_tokens)
        self.queue_depth_samples.append(queue_depth)
        self.active_samples.append(active)
        if self._last_tier is not None and tier != self._last_tier:
            self.tier_switches += 1
        self._last_tier = tier

    def on_spec_round(self, tier: str, *, drafted: int, accepted: int,
                      emitted: int):
        """One slot's draft/verify round: `drafted` = k draft tokens,
        `accepted` = the agreeing prefix length m in [0, k], `emitted`
        = tokens actually appended (m + 1 bonus, truncated at
        max_new_tokens / EOS)."""
        self.spec_rounds += 1
        self.spec_drafted += drafted
        self.spec_accepted += accepted
        self.spec_emitted += emitted
        self.spec_tier_rounds[tier] = self.spec_tier_rounds.get(tier, 0) + 1

    # -- aggregation -------------------------------------------------------

    def summary(self) -> dict:
        done = [r for r in self.requests.values() if r.finished is not None]
        ttfts = [r.ttft for r in done if r.ttft is not None]
        lats = [r.latency for r in done]
        gen = sum(r.generated_tokens for r in done)
        span = 0.0
        if done:
            t0 = min(r.arrival for r in done)
            t1 = max(r.finished for r in done)
            span = max(t1 - t0, 1e-9)
        total_steps = max(self.steps, 1)
        return {
            "requests_submitted": len(self.requests),
            "requests_completed": len(done),
            "generated_tokens": gen,
            "throughput_tok_s": gen / span if done else 0.0,
            "mean_ttft_s": sum(ttfts) / len(ttfts) if ttfts else 0.0,
            "p50_ttft_s": _percentile(ttfts, 50.0),
            "p95_ttft_s": _percentile(ttfts, 95.0),
            "max_ttft_s": max(ttfts) if ttfts else 0.0,
            "mean_latency_s": sum(lats) / len(lats) if lats else 0.0,
            "scheduler_steps": self.steps,
            "tier_switches": self.tier_switches,
            "mean_queue_depth": (sum(self.queue_depth_samples)
                                 / len(self.queue_depth_samples)
                                 if self.queue_depth_samples else 0.0),
            "max_queue_depth": max(self.queue_depth_samples, default=0),
            "mean_active_slots": (sum(self.active_samples)
                                  / len(self.active_samples)
                                  if self.active_samples else 0.0),
            "tier_occupancy": {t: n / total_steps
                               for t, n in sorted(self.tier_steps.items())},
            "tier_tokens": dict(sorted(self.tier_tokens.items())),
            "tier_decoded_tokens": dict(
                sorted(self.tier_decoded_tokens.items())),
            "tier_weight_bytes": dict(sorted(self.tier_weight_bytes.items())),
            "spec": self._spec_summary(),
            "kv": self._kv_summary(done),
        }

    def _kv_summary(self, done: list[RequestRecord]) -> dict:
        """Paged KV cache accounting (empty dict when the scheduler runs
        the dense slot-array path). `prefix_hit_rate` is the fraction of
        admitted requests that reused >= 1 shared prompt page;
        `shared_token_rate` the fraction of all prompt tokens served
        from shared pages; the hit/cold TTFT means quantify the reuse
        payoff the prefix cache exists for."""
        if self.kv_config is None:
            return {}
        admitted = [r for r in self.requests.values()
                    if r.admitted is not None]
        hits = [r for r in admitted if r.shared_prefix_tokens > 0]
        prompt_toks = sum(r.prompt_tokens for r in admitted)
        shared_toks = sum(r.shared_prefix_tokens for r in admitted)
        hit_ttfts = [r.ttft for r in hits if r.ttft is not None]
        cold_ttfts = [r.ttft for r in admitted
                      if r.shared_prefix_tokens == 0 and r.ttft is not None]
        # admission -> first token, i.e. pure prefill latency: unlike
        # arrival-based TTFT it is immune to queueing delay, so it
        # isolates what the prefix cache actually saves (hits prefill
        # only their suffix)
        hit_pf = [r.first_token - r.admitted for r in hits
                  if r.first_token is not None]
        cold_pf = [r.first_token - r.admitted for r in admitted
                   if r.shared_prefix_tokens == 0
                   and r.first_token is not None]
        res, wr = self.page_reserved_samples, self.page_written_samples
        total = max(self.page_total, 1)
        return {
            **self.kv_config,
            "prefix_hits": len(hits),
            "prefix_hit_rate": len(hits) / len(admitted) if admitted else 0.0,
            "shared_prefix_tokens": shared_toks,
            "shared_token_rate": (shared_toks / prompt_toks
                                  if prompt_toks else 0.0),
            "mean_ttft_hit_s": (sum(hit_ttfts) / len(hit_ttfts)
                                if hit_ttfts else 0.0),
            "mean_ttft_cold_s": (sum(cold_ttfts) / len(cold_ttfts)
                                 if cold_ttfts else 0.0),
            "mean_prefill_ttft_hit_s": (sum(hit_pf) / len(hit_pf)
                                        if hit_pf else 0.0),
            "mean_prefill_ttft_cold_s": (sum(cold_pf) / len(cold_pf)
                                         if cold_pf else 0.0),
            "mean_pages_reserved": sum(res) / len(res) if res else 0.0,
            "mean_pages_written": sum(wr) / len(wr) if wr else 0.0,
            "peak_pages_reserved": max(res, default=0),
            "peak_pages_written": max(wr, default=0),
            "reserved_occupancy": (max(res, default=0) / total),
            "written_occupancy": (max(wr, default=0) / total),
            "total_pages": self.page_total,
        }

    def _spec_summary(self) -> dict:
        """Speculative-decoding acceptance bookkeeping (all zeros when
        spec decode is off). `verify_steps` counts per-slot verify
        evaluations; with any acceptance at all it sits strictly below
        `emitted_tokens` -- the speed multiplier the self-speculative
        path exists for."""
        return {
            "rounds": self.spec_rounds,
            "drafted_tokens": self.spec_drafted,
            "accepted_tokens": self.spec_accepted,
            "emitted_tokens": self.spec_emitted,
            "verify_steps": self.spec_rounds,
            "acceptance_rate": (self.spec_accepted / self.spec_drafted
                                if self.spec_drafted else 0.0),
            "mean_accepted_prefix_len": (self.spec_emitted / self.spec_rounds
                                         if self.spec_rounds else 0.0),
            "verify_steps_per_token": (self.spec_rounds / self.spec_emitted
                                       if self.spec_emitted else 0.0),
            "tier_rounds": dict(sorted(self.spec_tier_rounds.items())),
        }


class FleetMetrics:
    """Fleet-level accounting over N replicas (serve/fleet.py).

    Request lifecycle is tracked at the FLEET boundary (submit ->
    dispatch -> finish), independent of which replica -- or how many,
    after requeues -- a request visits, so `requests_lost` is an
    end-to-end number: submitted minus completed after the fleet
    drains. Per-replica tier occupancy is sampled once per fleet step
    from each replica's live tier, which works identically for
    in-process and subprocess replicas (the latter report their tier in
    every step response).
    """

    def __init__(self):
        self.requests: dict[object, RequestRecord] = {}
        self.dispatch_replica: dict[object, int] = {}    # last dispatch
        self.dispatch_tier_index: dict[object, int] = {}
        self.priority_uids: set = set()
        self.steps = 0
        self.replica_tier_steps: dict[int, dict[str, int]] = {}
        self.queue_depth_samples: list[int] = []
        self.mean_bits_samples: list[float] = []
        self.tier_switches = 0
        self._last_indices: tuple | None = None
        self.requeued_requests = 0
        self.replica_failures: list[dict] = []
        self.straggler_events: dict[int, int] = {}

    # -- request lifecycle -------------------------------------------------

    def on_submit(self, uid, now: float, prompt_tokens: int,
                  priority: bool = False):
        self.requests[uid] = RequestRecord(
            uid=uid, arrival=now, prompt_tokens=prompt_tokens)
        if priority:
            self.priority_uids.add(uid)

    def on_dispatch(self, uid, replica: int, tier_index: int, now: float):
        rec = self.requests[uid]
        if rec.admitted is None:
            rec.admitted = now
        self.dispatch_replica[uid] = int(replica)
        self.dispatch_tier_index[uid] = int(tier_index)

    def on_finish(self, uid, now: float, generated_tokens: int):
        rec = self.requests[uid]
        rec.finished = now
        rec.generated_tokens = generated_tokens

    def on_requeue(self, uids, replica: int, now: float):
        self.requeued_requests += len(list(uids))

    def on_replica_failure(self, replica: int, reason: str, now: float):
        self.replica_failures.append(
            {"replica": int(replica), "reason": reason, "time": float(now)})

    def on_straggler(self, replica: int):
        self.straggler_events[replica] = (
            self.straggler_events.get(replica, 0) + 1)

    # -- per-step counters -------------------------------------------------

    def on_step(self, tier_names, tier_indices, mean_effective_bits: float,
                queue_depth: int):
        """One fleet step: each ALIVE replica's current tier name/index
        (dead replicas are skipped by the caller) plus the global queue
        depth and the router's fleet-wide mean effective bits."""
        self.steps += 1
        for rid, name in tier_names.items():
            per = self.replica_tier_steps.setdefault(rid, {})
            per[name] = per.get(name, 0) + 1
        self.queue_depth_samples.append(int(queue_depth))
        self.mean_bits_samples.append(float(mean_effective_bits))
        idx = tuple(sorted(tier_indices.items()))
        if self._last_indices is not None and idx != self._last_indices:
            self.tier_switches += sum(
                1 for (r, i), (r2, i2) in zip(idx, self._last_indices)
                if r == r2 and i != i2)
        self._last_indices = idx

    # -- aggregation -------------------------------------------------------

    def summary(self) -> dict:
        done = [r for r in self.requests.values() if r.finished is not None]
        lats = [r.latency for r in done if r.latency is not None]
        gen = sum(r.generated_tokens for r in done)
        span = 0.0
        if done:
            t0 = min(r.arrival for r in done)
            t1 = max(r.finished for r in done)
            span = max(t1 - t0, 1e-9)
        per_replica = {}
        for rid, steps in sorted(self.replica_tier_steps.items()):
            total = max(sum(steps.values()), 1)
            per_replica[str(rid)] = {
                "steps": sum(steps.values()),
                "tier_occupancy": {t: n / total
                                   for t, n in sorted(steps.items())},
                "requests": sum(1 for u, r in self.dispatch_replica.items()
                                if r == rid),
                "straggler_events": self.straggler_events.get(rid, 0),
            }
        return {
            "requests_submitted": len(self.requests),
            "requests_completed": len(done),
            "requests_lost": len(self.requests) - len(done),
            "requeued_requests": self.requeued_requests,
            "replica_failures": self.replica_failures,
            "priority_requests": len(self.priority_uids),
            "generated_tokens": gen,
            "throughput_tok_s": gen / span if done else 0.0,
            "mean_latency_s": sum(lats) / len(lats) if lats else 0.0,
            "p50_latency_s": _percentile(lats, 50.0),
            "p95_latency_s": _percentile(lats, 95.0),
            "fleet_steps": self.steps,
            "tier_switches": self.tier_switches,
            "mean_queue_depth": (sum(self.queue_depth_samples)
                                 / len(self.queue_depth_samples)
                                 if self.queue_depth_samples else 0.0),
            "max_queue_depth": max(self.queue_depth_samples, default=0),
            "mean_effective_bits_mean": (sum(self.mean_bits_samples)
                                         / len(self.mean_bits_samples)
                                         if self.mean_bits_samples else 0.0),
            "mean_effective_bits_min": min(self.mean_bits_samples,
                                           default=0.0),
            "per_replica": per_replica,
        }
