"""Serving metrics: per-request latency/TTFT and per-step tier counters.

The scheduler feeds this with explicit timestamps (a `clock()` float,
wall time in the live driver, a virtual clock in tests), so the module
is deterministic under test. `summary()` flattens everything into a
plain dict of floats/ints that the benchmarks serialize as
BENCH_serve.json.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class RequestRecord:
    uid: object
    arrival: float
    admitted: float | None = None
    first_token: float | None = None
    finished: float | None = None
    prompt_tokens: int = 0
    generated_tokens: int = 0
    admit_tier: str = ""

    @property
    def ttft(self) -> float | None:
        if self.first_token is None:
            return None
        return self.first_token - self.arrival

    @property
    def latency(self) -> float | None:
        if self.finished is None:
            return None
        return self.finished - self.arrival


class ServeMetrics:
    """Aggregates the continuous-batching scheduler's counters."""

    def __init__(self):
        self.requests: dict[object, RequestRecord] = {}
        self.steps = 0
        self.tier_steps: dict[str, int] = {}
        self.tier_tokens: dict[str, int] = {}
        self.queue_depth_samples: list[int] = []
        self.active_samples: list[int] = []
        self.tier_switches = 0
        self.tier_weight_bytes: dict[str, dict] = {}
        self._last_tier: str | None = None

    # -- request lifecycle -------------------------------------------------

    def on_submit(self, uid, now: float, prompt_tokens: int):
        self.requests[uid] = RequestRecord(
            uid=uid, arrival=now, prompt_tokens=prompt_tokens)

    def on_admit(self, uid, now: float, tier: str):
        rec = self.requests[uid]
        rec.admitted = now
        rec.admit_tier = tier

    def on_first_token(self, uid, now: float):
        rec = self.requests[uid]
        if rec.first_token is None:
            rec.first_token = now

    def on_finish(self, uid, now: float, generated_tokens: int):
        rec = self.requests[uid]
        rec.finished = now
        rec.generated_tokens = generated_tokens

    def on_tier_bytes(self, tier: str, *, packed_bits, packed_nbytes: int,
                      weight_nbytes: int, effective_bits: float = 0.0,
                      per_device_plane_nbytes: int = 0):
        """Record the measured HBM weight footprint of a served tier
        (fed by the scheduler on every tier activation, so the
        downgrade -> fewer-weight-bytes claim is a reported number).
        `effective_bits` is the Table 7 accounting of the served planes
        (base bits + overflow fraction for extra-precision tiers);
        `per_device_plane_nbytes` is the largest single-device shard of
        the plane bytes (== packed_nbytes / model_parallel on a TP
        serving mesh, == packed_nbytes off-mesh or when 0 is fed)."""
        self.tier_weight_bytes[tier] = {
            "packed_bits": packed_bits,
            "packed_nbytes": int(packed_nbytes),
            "weight_nbytes": int(weight_nbytes),
            "effective_bits": float(effective_bits),
            "per_device_plane_nbytes": int(per_device_plane_nbytes
                                           or packed_nbytes),
        }

    # -- per-step counters -------------------------------------------------

    def on_step(self, tier: str, *, new_tokens: int, active: int,
                queue_depth: int):
        self.steps += 1
        self.tier_steps[tier] = self.tier_steps.get(tier, 0) + 1
        self.tier_tokens[tier] = self.tier_tokens.get(tier, 0) + new_tokens
        self.queue_depth_samples.append(queue_depth)
        self.active_samples.append(active)
        if self._last_tier is not None and tier != self._last_tier:
            self.tier_switches += 1
        self._last_tier = tier

    # -- aggregation -------------------------------------------------------

    def summary(self) -> dict:
        done = [r for r in self.requests.values() if r.finished is not None]
        ttfts = [r.ttft for r in done if r.ttft is not None]
        lats = [r.latency for r in done]
        gen = sum(r.generated_tokens for r in done)
        span = 0.0
        if done:
            t0 = min(r.arrival for r in done)
            t1 = max(r.finished for r in done)
            span = max(t1 - t0, 1e-9)
        total_steps = max(self.steps, 1)
        return {
            "requests_submitted": len(self.requests),
            "requests_completed": len(done),
            "generated_tokens": gen,
            "throughput_tok_s": gen / span if done else 0.0,
            "mean_ttft_s": sum(ttfts) / len(ttfts) if ttfts else 0.0,
            "max_ttft_s": max(ttfts) if ttfts else 0.0,
            "mean_latency_s": sum(lats) / len(lats) if lats else 0.0,
            "scheduler_steps": self.steps,
            "tier_switches": self.tier_switches,
            "mean_queue_depth": (sum(self.queue_depth_samples)
                                 / len(self.queue_depth_samples)
                                 if self.queue_depth_samples else 0.0),
            "max_queue_depth": max(self.queue_depth_samples, default=0),
            "mean_active_slots": (sum(self.active_samples)
                                  / len(self.active_samples)
                                  if self.active_samples else 0.0),
            "tier_occupancy": {t: n / total_steps
                               for t, n in sorted(self.tier_steps.items())},
            "tier_tokens": dict(sorted(self.tier_tokens.items())),
            "tier_weight_bytes": dict(sorted(self.tier_weight_bytes.items())),
        }
