"""Elastic-precision routing: load -> served precision tier.

The MatQuant deployment story (paper §5.4) stores ONE int8 parent
checkpoint; any sliced precision of it is a valid model. That turns
precision into a runtime knob: when the request queue grows past what
the current tier can drain, the router downgrades (int8 -> int4 ->
Mix'n'Match ~3.x -> int2), trading quality for ~2x decode-arithmetic
savings per step down; when load subsides it recovers toward int8.

Downgrades apply immediately (load spikes need an immediate response);
upgrades require the measured load to sit below the lower tier's
threshold for `cooldown` consecutive observations (hysteresis, so the
scheduler does not thrash across a threshold).

`TierCache` owns the parent params and materializes each tier's served
weights on first use; afterwards a switch is a dict lookup (O(1)), so
the scheduler can flip tiers between two decode steps. Two layouts:

  * dequantized (packed=False): every tier shares one pytree structure
    and dtype, so ONE jitted decode step serves all tiers with no
    recompile on a switch.
  * packed (packed=True): every tier becomes packed r-bit planes
    sliced from a single pre-packed int8 parent
    (`engine.build_packed_parent` + `PackedLinear.materialize`) -- the
    representation the Pallas kernel actually reads, so a downgrade
    cuts HBM weight bytes per step. Uniform-int tiers keep stacked
    planes (incl. MoE expert stacks, consumed batched-over-experts);
    Mix'n'Match tiers store per-layer planes, each layer sliced at its
    own r (layers unstacked into a list -- plane shapes depend on r).
    Packed plane shapes depend on the representation, so the scheduler
    keeps one compiled step per `TierEntry.packed_bits` key (an int for
    uniform tiers, the per-layer bits tuple for Mix'n'Match; lazily
    warmed, a dict lookup on revisit).

`get` returns a `TierEntry` carrying the params, the packed key
(None on the dequantized path) and measured weight bytes, so the
scheduler/benchmarks report the bytes claim instead of asserting it.
"""

from __future__ import annotations

import dataclasses

from repro.core import mixnmatch


@dataclasses.dataclass(frozen=True)
class PrecisionTier:
    """A servable precision of the parent checkpoint.

    bits: int (uniform slice) or a per-layer tuple (Mix'n'Match).
    """
    name: str
    bits: int | tuple[int, ...]

    @property
    def effective_bits(self) -> float:
        if isinstance(self.bits, int):
            return float(self.bits)
        return mixnmatch.effective_bits(self.bits)


def default_tiers(num_layers: int) -> tuple[PrecisionTier, ...]:
    """int8 -> int4 -> Mix'n'Match ~3.3 -> int2, best quality first."""
    mnm = tuple(mixnmatch.assign(num_layers, 3.3, "pyramid"))
    return (
        PrecisionTier("int8", 8),
        PrecisionTier("int4", 4),
        PrecisionTier(f"mixnmatch{mixnmatch.effective_bits(mnm):.1f}", mnm),
        PrecisionTier("int2", 2),
    )


class ElasticPrecisionRouter:
    """Maps a scalar load signal to a tier index with hysteresis.

    thresholds[i] is the load above which tier i is insufficient: with
    tiers (int8, int4, mnm, int2) and thresholds (4, 8, 16), load <= 4
    serves int8, 4 < load <= 8 serves int4, ..., load > 16 serves int2.
    The load signal the scheduler feeds is queue depth + a backlog term
    (queued prompt tokens / slot capacity), so both many small requests
    and few huge ones push precision down.
    """

    def __init__(self, tiers, thresholds=None, cooldown: int = 4):
        self.tiers = tuple(tiers)
        if thresholds is None:
            thresholds = tuple(4 * 2**i for i in range(len(self.tiers) - 1))
        assert len(thresholds) == len(self.tiers) - 1
        assert list(thresholds) == sorted(thresholds)
        self.thresholds = tuple(float(t) for t in thresholds)
        self.cooldown = cooldown
        self.index = 0                 # serving tiers[0] (best quality)
        self._calm_steps = 0

    @property
    def tier(self) -> PrecisionTier:
        return self.tiers[self.index]

    def reset(self):
        self.index = 0
        self._calm_steps = 0

    def desired_index(self, load: float) -> int:
        for i, thr in enumerate(self.thresholds):
            if load <= thr:
                return i
        return len(self.tiers) - 1

    def observe(self, load: float) -> PrecisionTier:
        """Feed one load measurement; returns the tier to serve NOW."""
        desired = self.desired_index(load)
        if desired > self.index:               # overload: drop immediately
            self.index = desired
            self._calm_steps = 0
        elif desired < self.index:             # calm: recover with hysteresis
            self._calm_steps += 1
            if self._calm_steps >= self.cooldown:
                self.index -= 1                # one tier at a time
                self._calm_steps = 0
        else:
            self._calm_steps = 0
        return self.tiers[self.index]


@dataclasses.dataclass(frozen=True)
class TierEntry:
    """One materialized, servable tier.

    packed_bits: hashable key of the packed representation (selects the
      scheduler's compiled closure): the static bitwidth for a uniform
      tier, the per-layer bits TUPLE for a packed Mix'n'Match tier, or
      None for the dequantized layout.
    packed_nbytes: bytes of the sliced weight planes as served -- the
      HBM weight traffic of one decode step, shrinking with the tier's
      per-layer bit sum (2x per uniform step down int8 -> int4 -> int2,
      in between for Mix'n'Match).
    weight_nbytes: packed_nbytes plus the tier-independent per-channel
      scales (alpha/beta).
    """
    name: str
    params: object = dataclasses.field(repr=False)
    packed_bits: int | tuple[int, ...] | None = None
    packed_nbytes: int = 0
    weight_nbytes: int = 0


class TierCache:
    """Lazily materialized served params per tier, keyed by tier name.

    packed=True serves EVERY tier as packed r-bit planes sliced from
    one pre-packed int8 parent (built once, on first use): uniform-int
    tiers as stacked planes, per-layer Mix'n'Match tiers as per-layer
    planes (each layer at its own r, layers unstacked into a list).
    `get` returns a TierEntry.
    """

    def __init__(self, parent_params, cfg, *, extra_precision: bool = False,
                 packed: bool = False):
        from repro.serve import engine as _engine   # avoid import cycle
        if packed and extra_precision:
            raise ValueError("packed tier serving does not support "
                             "extra_precision")
        self._engine = _engine
        self.parent_params = parent_params
        self.cfg = cfg
        self.extra_precision = extra_precision
        self.packed = packed
        self._cache: dict[str, TierEntry] = {}
        self._packed_parent = None      # {path: PackedLinear}, built once

    def _entry(self, tier: PrecisionTier, params, packed_bits):
        plane, total = self._engine.served_weight_nbytes(params, self.cfg)
        return TierEntry(name=tier.name, params=params,
                         packed_bits=packed_bits,
                         packed_nbytes=plane, weight_nbytes=total)

    def get(self, tier: PrecisionTier) -> TierEntry:
        if tier.name not in self._cache:
            if self.packed:
                if self._packed_parent is None:
                    self._packed_parent = self._engine.build_packed_parent(
                        self.parent_params, self.cfg)
                uniform = isinstance(tier.bits, int)
                params = self._engine.materialize_packed_params(
                    self.parent_params, self.cfg,
                    tier.bits if uniform else list(tier.bits),
                    parent=self._packed_parent)
                packed_bits = tier.bits if uniform else tuple(tier.bits)
            else:
                bits = (tier.bits if isinstance(tier.bits, int)
                        else list(tier.bits))
                params = self._engine.materialize_served_params(
                    self.parent_params, self.cfg, bits, self.extra_precision)
                packed_bits = None
            self._cache[tier.name] = self._entry(tier, params, packed_bits)
        return self._cache[tier.name]

    def seed(self, tier: PrecisionTier, params, packed_bits=None):
        """Adopt already-materialized served params for `tier` (e.g. the
        engine's own fixed tier) instead of building a second copy."""
        self._cache[tier.name] = self._entry(tier, params, packed_bits)

    @property
    def materialized(self) -> list[str]:
        return sorted(self._cache)
