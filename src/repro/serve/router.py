"""Elastic-precision routing: load -> served precision tier.

The MatQuant deployment story (paper §5.4) stores ONE int8 parent
checkpoint; any sliced precision of it is a valid model. That turns
precision into a runtime knob: when the request queue grows past what
the current tier can drain, the router downgrades (int8 -> int4 ->
Mix'n'Match ~3.x -> extra-precision int2 -> int2), trading quality for
~2x decode-arithmetic savings per step down; when load subsides it
recovers toward int8. The extra-precision rung (Errata Eq. 8) spends a
1-bit overflow bitmap on the int2 plane -- the paper's strongest
low-bit representation, ~6% better than plain int2 at ~2.05 effective
bits -- so the ladder degrades through it before giving up the
overflow bucket entirely.

Downgrades apply immediately (load spikes need an immediate response);
upgrades require the measured load to sit below the lower tier's
threshold for `cooldown` consecutive observations (hysteresis, so the
scheduler does not thrash across a threshold).

`FleetRouter` lifts the same ladder + hysteresis to N data-parallel
replicas (serve/fleet.py): one global load signal buys a budget of
downgrade steps spent concentrate-first on the least-loaded replicas,
with >= 1 pinned replica never dropping below int4 so priority traffic
keeps a high-bit home.

`TierCache` owns the parent params and materializes each tier's served
weights on first use; afterwards a switch is a dict lookup (O(1)), so
the scheduler can flip tiers between two decode steps. Two layouts:

  * dequantized (packed=False): every tier shares one pytree structure
    and dtype, so ONE jitted decode step serves all tiers with no
    recompile on a switch.
  * packed (packed=True): every tier becomes packed r-bit planes
    sliced from a single pre-packed int8 parent
    (`engine.build_packed_parent` + `PackedLinear.materialize`) -- the
    representation the Pallas kernel actually reads, so a downgrade
    cuts HBM weight bytes per step. Uniform-int tiers keep stacked
    planes (incl. MoE expert stacks, consumed batched-over-experts);
    Mix'n'Match tiers store per-layer planes, each layer sliced at its
    own r (layers unstacked into a list -- plane shapes depend on r);
    extra-precision tiers additionally carry the packed 1-bit overflow
    bitmap on every plane (PackedPlane.overflow), composed in-kernel.
    Packed plane shapes/structures depend on the representation, so
    the scheduler keeps one compiled step per `TierEntry.packed_bits`
    key (`PrecisionTier.packed_key`; lazily warmed, a dict lookup on
    revisit).

`get` returns a `TierEntry` carrying the params, the packed key
(None on the dequantized path) and measured weight bytes/effective
bits, so the scheduler/benchmarks report the bytes claim instead of
asserting it.
"""

from __future__ import annotations

import dataclasses

from repro.core import mixnmatch, packing


@dataclasses.dataclass(frozen=True)
class PrecisionTier:
    """A servable precision of the parent checkpoint.

    bits: int (uniform slice) or a per-layer tuple (Mix'n'Match).
    extra_precision: Errata Eq. 8 -- serve the overflow bucket as a
      1-bit bitmap plane on top of the `bits`-bit base plane.

    This dataclass is the ONE place a tier's identity lives: the
    router ladder orders instances of it, `TierCache` materializes
    from its fields, and `packed_key` is the representation key the
    scheduler compiles one step closure per. Adding a tier to the
    ladder is a single `default_tiers` edit.
    """
    name: str
    bits: int | tuple[int, ...]
    extra_precision: bool = False

    @property
    def effective_bits(self) -> float:
        """STORED bits/weight of the tier (ladder ordering + roofline).

        For an extra-precision tier this counts the densely stored
        1-bit bitmap (r + 1); the paper's Table 7 effective bits
        (r + overflow fraction, ~2.05 for int2+ep) depend on the
        weights and are measured at materialization time
        (`TierEntry.effective_bits`).
        """
        base = (float(self.bits) if isinstance(self.bits, int)
                else mixnmatch.effective_bits(self.bits))
        return base + 1.0 if self.extra_precision else base

    @property
    def packed_key(self):
        """Hashable packed-representation key (see packing.packed_rep_key)."""
        return packing.packed_rep_key(self.bits, self.extra_precision)


def default_tiers(num_layers: int) -> tuple[PrecisionTier, ...]:
    """int8 -> int4 -> Mix'n'Match ~3.3 -> int2+ep -> int2, best first.

    The int2+ep rung stores 3 bits/weight (2-bit plane + dense 1-bit
    overflow bitmap) -- between Mix'n'Match ~3.3 and int2 in HBM bytes
    -- and serves ~2.05 Table-7 effective bits."""
    mnm = tuple(mixnmatch.assign(num_layers, 3.3, "pyramid"))
    return (
        PrecisionTier("int8", 8),
        PrecisionTier("int4", 4),
        PrecisionTier(f"mixnmatch{mixnmatch.effective_bits(mnm):.1f}", mnm),
        PrecisionTier("int2+ep", 2, extra_precision=True),
        PrecisionTier("int2", 2),
    )


class ElasticPrecisionRouter:
    """Maps a scalar load signal to a tier index with hysteresis.

    thresholds[i] is the load above which tier i is insufficient: with
    tiers (int8, int4, mnm, int2+ep, int2) and thresholds (4, 8, 16,
    32), load <= 4 serves int8, 4 < load <= 8 serves int4, ..., load >
    32 serves int2. The load signal the scheduler feeds is queue depth
    + a backlog term (queued prompt tokens / slot capacity), so both
    many small requests and few huge ones push precision down.
    """

    def __init__(self, tiers, thresholds=None, cooldown: int = 4):
        self.tiers = tuple(tiers)
        if thresholds is None:
            thresholds = tuple(4 * 2**i for i in range(len(self.tiers) - 1))
        assert len(thresholds) == len(self.tiers) - 1
        assert list(thresholds) == sorted(thresholds)
        self.thresholds = tuple(float(t) for t in thresholds)
        self.cooldown = cooldown
        self.index = 0                 # serving tiers[0] (best quality)
        self._calm_steps = 0

    @property
    def tier(self) -> PrecisionTier:
        return self.tiers[self.index]

    def reset(self):
        self.index = 0
        self._calm_steps = 0

    def desired_index(self, load: float) -> int:
        for i, thr in enumerate(self.thresholds):
            if load <= thr:
                return i
        return len(self.tiers) - 1

    def observe(self, load: float) -> PrecisionTier:
        """Feed one load measurement; returns the tier to serve NOW."""
        desired = self.desired_index(load)
        if desired > self.index:               # overload: drop immediately
            self.index = desired
            self._calm_steps = 0
        elif desired < self.index:             # calm: recover with hysteresis
            self._calm_steps += 1
            if self._calm_steps >= self.cooldown:
                self.index -= 1                # one tier at a time
                self._calm_steps = 0
        else:
            self._calm_steps = 0
        return self.tiers[self.index]


class FleetRouter:
    """Per-replica tier assignment for N data-parallel replicas.

    The single-replica router downgrades EVERYONE when load crosses a
    threshold; at fleet scale that is the wrong shape -- shedding load
    should cost quality on the replicas that can spare it, not on every
    request in flight. This router maps one global load signal to a
    BUDGET of downgrade steps (`thresholds[s]` is the load above which
    the fleet owes s+1 steps, so the budget is monotone in load) and
    spends the budget concentrate-first: the replica earliest in fill
    order absorbs rungs down to its floor before the next replica gives
    up anything, so moderate overload degrades SOME replicas while the
    rest keep serving int8.

    Fill order is computed per observation: already-downgraded replicas
    first (deepest first -- assignments are sticky, so a shifting
    least-loaded ordering does not bounce the downgrade between
    replicas), then colder replicas before hotter ones ("downgrade the
    least-loaded first": the busy replicas are the ones serving the
    latency-sensitive bulk). Pinned replicas fill LAST and never drop
    below `pin_floor` (default tier index 1 = int4), so priority /
    deadline traffic dispatched to them never lands on a sub-int4
    replica no matter the load.

    Recovery reuses the single-router hysteresis semantics per replica:
    a downgrade applies immediately, an upgrade needs `cooldown`
    consecutive calm observations and then climbs ONE rung at a time --
    a replica recovering from int2 always passes through int2+ep.
    """

    def __init__(self, tiers, num_replicas: int, thresholds=None,
                 cooldown: int = 4, pinned=(0,), pin_floor: int = 1):
        assert num_replicas >= 1
        self.tiers = tuple(tiers)
        self.num_replicas = num_replicas
        steps = num_replicas * (len(self.tiers) - 1)
        if thresholds is None:
            # linear ramp: each additional `base` units of global load
            # buys one more downgrade step somewhere in the fleet
            thresholds = tuple(4.0 * (s + 1) for s in range(steps))
        assert len(thresholds) == steps
        assert list(thresholds) == sorted(thresholds)
        self.thresholds = tuple(float(t) for t in thresholds)
        self.cooldown = cooldown
        self.pinned = frozenset(int(r) for r in pinned)
        assert all(0 <= r < num_replicas for r in self.pinned)
        self.pin_floor = int(pin_floor)
        self.indices = [0] * num_replicas
        self._calm = [0] * num_replicas

    @property
    def tier_by_replica(self) -> tuple[PrecisionTier, ...]:
        return tuple(self.tiers[i] for i in self.indices)

    def reset(self):
        self.indices = [0] * self.num_replicas
        self._calm = [0] * self.num_replicas

    def floor(self, replica: int) -> int:
        """Deepest tier index replica may reach (pin caps the drop)."""
        if replica in self.pinned:
            return min(self.pin_floor, len(self.tiers) - 1)
        return len(self.tiers) - 1

    def desired_steps(self, load: float) -> int:
        """Total downgrade-step budget owed at `load` (monotone)."""
        s = 0
        for thr in self.thresholds:
            if load > thr:
                s += 1
        return s

    def desired_indices(self, load: float, order=None) -> tuple[int, ...]:
        """Budget spent concentrate-first along `order` (default: replica
        id order). For ANY fixed order this is pointwise monotone in
        `load`: a larger budget only ever extends the fill prefix."""
        if order is None:
            order = range(self.num_replicas)
        budget = self.desired_steps(load)
        out = [0] * self.num_replicas
        for r in order:
            take = min(budget, self.floor(r))
            out[r] = take
            budget -= take
            if budget <= 0:
                break
        return tuple(out)

    def _fill_order(self, replica_loads) -> list[int]:
        """Sticky concentrate order: deepest-downgraded first, then
        least-loaded, pinned replicas always at the tail."""
        def key(r):
            return (-self.indices[r], float(replica_loads[r]), r)
        unpinned = [r for r in range(self.num_replicas)
                    if r not in self.pinned]
        return sorted(unpinned, key=key) + sorted(self.pinned, key=key)

    def observe(self, load: float, replica_loads) -> tuple[PrecisionTier, ...]:
        """Feed one global load + per-replica loads; returns the tier
        each replica serves NOW."""
        assert len(replica_loads) == self.num_replicas
        desired = self.desired_indices(load, self._fill_order(replica_loads))
        for r in range(self.num_replicas):
            if desired[r] > self.indices[r]:   # overload: drop immediately
                self.indices[r] = desired[r]
                self._calm[r] = 0
            elif desired[r] < self.indices[r]:  # calm: hysteresis recovery
                self._calm[r] += 1
                if self._calm[r] >= self.cooldown:
                    self.indices[r] -= 1        # one rung at a time
                    self._calm[r] = 0
            else:
                self._calm[r] = 0
        return self.tier_by_replica

    def mean_effective_bits(self) -> float:
        """Fleet-wide mean of the served tiers' nominal effective bits
        (strictly decreasing down the ladder, so pointwise-deeper
        assignments always push this down)."""
        return (sum(self.tiers[i].effective_bits for i in self.indices)
                / self.num_replicas)


@dataclasses.dataclass(frozen=True)
class TierEntry:
    """One materialized, servable tier.

    packed_bits: hashable key of the packed representation (selects the
      scheduler's compiled closure; `PrecisionTier.packed_key`): the
      static bitwidth for a uniform tier, the per-layer bits TUPLE for
      a packed Mix'n'Match tier, `(key, "ep")` for an extra-precision
      tier, or None for the dequantized layout.
    packed_nbytes: bytes of the sliced weight planes as served
      (including the ep overflow bitmaps) -- the HBM weight traffic of
      one decode step, shrinking with the tier's per-layer bit sum
      (2x per uniform step down int8 -> int4 -> int2; Mix'n'Match and
      int2+ep land in between).
    weight_nbytes: packed_nbytes plus the tier-independent per-channel
      scales (alpha/beta).
    effective_bits: measured bits/weight of the served planes under the
      paper's Table 7 accounting -- plane bits plus one bit per weight
      that actually lands in the overflow bucket (~2.05 for int2+ep),
      NOT the dense bitmap storage cost. Falls back to the tier's
      nominal effective bits on the dequantized path.
    per_device_plane_nbytes: largest single-device shard of the plane
      bytes -- packed_nbytes / model_parallel on a TP mesh whose
      'model' axis divides every plane's sharded dim, == packed_nbytes
      off-mesh.
    shardings: NamedSharding tree the params were placed with (None
      off-mesh); the scheduler compiles its per-representation step
      closures against it.
    """
    name: str
    params: object = dataclasses.field(repr=False)
    packed_bits: int | tuple | None = None
    packed_nbytes: int = 0
    weight_nbytes: int = 0
    effective_bits: float = 0.0
    per_device_plane_nbytes: int = 0
    shardings: object = dataclasses.field(default=None, repr=False)


class TierCache:
    """Lazily materialized served params per tier, keyed by tier name.

    packed=True serves EVERY tier as packed r-bit planes sliced from
    one pre-packed int8 parent (built once, on first use): uniform-int
    tiers as stacked planes, per-layer Mix'n'Match tiers as per-layer
    planes (each layer at its own r, layers unstacked into a list),
    extra-precision tiers with the packed overflow bitmap on each
    plane. `get` returns a TierEntry.

    `extra_precision=True` (the cache-wide flag, from
    ServeConfig.extra_precision) promotes EVERY tier to its ep variant
    -- tiers that flag ep themselves (the ladder's int2+ep rung) get it
    regardless.

    `mesh` (a `(data, model)` serving mesh) re-materializes every tier
    DIRECTLY into sharded buffers: the freshly sliced planes are
    `jax.device_put` with the `engine.served_param_shardings` target
    tree -- a device-to-device placement, never a host gather -- so a
    mid-flight tier switch hands the scheduler params that already live
    where its sharded step closure expects them, and per-device plane
    bytes (`TierEntry.per_device_plane_nbytes`) divide by the mesh's
    model-parallel degree.
    """

    def __init__(self, parent_params, cfg, *, extra_precision: bool = False,
                 packed: bool = False, mesh=None):
        from repro.serve import engine as _engine   # avoid import cycle
        self._engine = _engine
        self.parent_params = parent_params
        self.cfg = cfg
        self.extra_precision = extra_precision
        self.packed = packed
        self.mesh = mesh
        self._cache: dict[str, TierEntry] = {}
        # packed representation key -> first tier name serving it: two
        # rungs that normalize to the SAME representation (e.g. int2 and
        # int2+ep under the cache-wide ep flag) share one params copy
        # instead of materializing byte-identical planes twice
        self._by_key: dict[object, str] = {}
        self._packed_parent = None      # {path: PackedLinear}, built once

    def _place(self, params):
        """Shard freshly materialized params onto the serving mesh.

        `device_put` with the resolved NamedSharding tree moves each
        plane shard device-to-device (no host round-trip); off-mesh it
        is the identity with shardings=None."""
        if self.mesh is None:
            return params, None
        import jax
        shardings = self._engine.served_param_shardings(
            params, self.cfg, self.mesh)
        return jax.device_put(params, shardings), shardings

    def _entry(self, tier: PrecisionTier, params, packed_bits,
               shardings=None):
        plane, total, per_dev = self._engine.served_nbytes(params, self.cfg)
        eff = self._engine.served_effective_bits(params)
        return TierEntry(name=tier.name, params=params,
                         packed_bits=packed_bits,
                         packed_nbytes=plane, weight_nbytes=total,
                         effective_bits=(tier.effective_bits if eff is None
                                         else eff),
                         per_device_plane_nbytes=per_dev,
                         shardings=shardings)

    def get(self, tier: PrecisionTier) -> TierEntry:
        if self.extra_precision and not tier.extra_precision:
            tier = dataclasses.replace(tier, extra_precision=True)
        if tier.name not in self._cache:
            shardings = None
            if self.packed:
                packed_bits = tier.packed_key
                alias = self._by_key.get(packed_bits)
                if alias is not None:
                    # same representation already materialized under
                    # another rung name: share its params (+ placement)
                    params = self._cache[alias].params
                    shardings = self._cache[alias].shardings
                else:
                    if self._packed_parent is None:
                        self._packed_parent = self._engine.build_packed_parent(
                            self.parent_params, self.cfg)
                    uniform = isinstance(tier.bits, int)
                    params = self._engine.materialize_packed_params(
                        self.parent_params, self.cfg,
                        tier.bits if uniform else list(tier.bits),
                        parent=self._packed_parent,
                        extra_precision=tier.extra_precision)
                    params, shardings = self._place(params)
                    self._by_key[packed_bits] = tier.name
            else:
                bits = (tier.bits if isinstance(tier.bits, int)
                        else list(tier.bits))
                params = self._engine.materialize_served_params(
                    self.parent_params, self.cfg, bits, tier.extra_precision)
                params, shardings = self._place(params)
                packed_bits = None
            self._cache[tier.name] = self._entry(tier, params, packed_bits,
                                                 shardings)
        return self._cache[tier.name]

    def seed(self, tier: PrecisionTier, params, packed_bits=None):
        """Adopt already-materialized served params for `tier` (e.g. the
        engine's own fixed tier) instead of building a second copy. On a
        mesh the placement is re-resolved; for params the engine already
        sharded this is a no-op device_put."""
        if self.extra_precision and not tier.extra_precision:
            tier = dataclasses.replace(tier, extra_precision=True)
        if self.packed and packed_bits is not None:
            self._by_key.setdefault(packed_bits, tier.name)
        params, shardings = self._place(params)
        self._cache[tier.name] = self._entry(tier, params, packed_bits,
                                             shardings)

    @property
    def materialized(self) -> list[str]:
        return sorted(self._cache)
