"""Elastic-precision routing: load -> served precision tier.

The MatQuant deployment story (paper §5.4) stores ONE int8 parent
checkpoint; any sliced precision of it is a valid model. That turns
precision into a runtime knob: when the request queue grows past what
the current tier can drain, the router downgrades (int8 -> int4 ->
Mix'n'Match ~3.x -> int2), trading quality for ~2x decode-arithmetic
savings per step down; when load subsides it recovers toward int8.

Downgrades apply immediately (load spikes need an immediate response);
upgrades require the measured load to sit below the lower tier's
threshold for `cooldown` consecutive observations (hysteresis, so the
scheduler does not thrash across a threshold).

`TierCache` owns the parent params and materializes each tier's sliced
weights on first use via `materialize_served_params` /
`materialize_packed_params`; afterwards a switch is a dict lookup
(O(1)), so the scheduler can flip tiers between two decode steps. All
tiers share the same pytree structure and dtypes, so the jitted decode
step never recompiles on a switch.
"""

from __future__ import annotations

import dataclasses

from repro.core import mixnmatch


@dataclasses.dataclass(frozen=True)
class PrecisionTier:
    """A servable precision of the parent checkpoint.

    bits: int (uniform slice) or a per-layer tuple (Mix'n'Match).
    """
    name: str
    bits: int | tuple[int, ...]

    @property
    def effective_bits(self) -> float:
        if isinstance(self.bits, int):
            return float(self.bits)
        return mixnmatch.effective_bits(self.bits)


def default_tiers(num_layers: int) -> tuple[PrecisionTier, ...]:
    """int8 -> int4 -> Mix'n'Match ~3.3 -> int2, best quality first."""
    mnm = tuple(mixnmatch.assign(num_layers, 3.3, "pyramid"))
    return (
        PrecisionTier("int8", 8),
        PrecisionTier("int4", 4),
        PrecisionTier(f"mixnmatch{mixnmatch.effective_bits(mnm):.1f}", mnm),
        PrecisionTier("int2", 2),
    )


class ElasticPrecisionRouter:
    """Maps a scalar load signal to a tier index with hysteresis.

    thresholds[i] is the load above which tier i is insufficient: with
    tiers (int8, int4, mnm, int2) and thresholds (4, 8, 16), load <= 4
    serves int8, 4 < load <= 8 serves int4, ..., load > 16 serves int2.
    The load signal the scheduler feeds is queue depth + a backlog term
    (queued prompt tokens / slot capacity), so both many small requests
    and few huge ones push precision down.
    """

    def __init__(self, tiers, thresholds=None, cooldown: int = 4):
        self.tiers = tuple(tiers)
        if thresholds is None:
            thresholds = tuple(4 * 2**i for i in range(len(self.tiers) - 1))
        assert len(thresholds) == len(self.tiers) - 1
        assert list(thresholds) == sorted(thresholds)
        self.thresholds = tuple(float(t) for t in thresholds)
        self.cooldown = cooldown
        self.index = 0                 # serving tiers[0] (best quality)
        self._calm_steps = 0

    @property
    def tier(self) -> PrecisionTier:
        return self.tiers[self.index]

    def reset(self):
        self.index = 0
        self._calm_steps = 0

    def desired_index(self, load: float) -> int:
        for i, thr in enumerate(self.thresholds):
            if load <= thr:
                return i
        return len(self.tiers) - 1

    def observe(self, load: float) -> PrecisionTier:
        """Feed one load measurement; returns the tier to serve NOW."""
        desired = self.desired_index(load)
        if desired > self.index:               # overload: drop immediately
            self.index = desired
            self._calm_steps = 0
        elif desired < self.index:             # calm: recover with hysteresis
            self._calm_steps += 1
            if self._calm_steps >= self.cooldown:
                self.index -= 1                # one tier at a time
                self._calm_steps = 0
        else:
            self._calm_steps = 0
        return self.tiers[self.index]


class TierCache:
    """Lazily materialized served params per tier, keyed by tier name.

    packed=True routes through materialize_packed_params (TPU kernel
    consumable planes; uniform-int tiers only) instead of the
    dequantized-weights path.
    """

    def __init__(self, parent_params, cfg, *, extra_precision: bool = False,
                 packed: bool = False):
        from repro.serve import engine as _engine   # avoid import cycle
        self._engine = _engine
        self.parent_params = parent_params
        self.cfg = cfg
        self.extra_precision = extra_precision
        self.packed = packed
        self._cache: dict[str, object] = {}

    def get(self, tier: PrecisionTier):
        if tier.name not in self._cache:
            bits = tier.bits if isinstance(tier.bits, int) else list(tier.bits)
            if self.packed:
                if not isinstance(bits, int):
                    raise ValueError(
                        "packed serving needs uniform integer bits; "
                        f"tier {tier.name} is per-layer")
                self._cache[tier.name] = self._engine.materialize_packed_params(
                    self.parent_params, self.cfg, bits)
            else:
                self._cache[tier.name] = self._engine.materialize_served_params(
                    self.parent_params, self.cfg, bits, self.extra_precision)
        return self._cache[tier.name]

    @property
    def materialized(self) -> list[str]:
        return sorted(self._cache)
