"""Continuous-batching scheduler with elastic-precision serving.

Requests arrive at any time, are queued, and are admitted into free
rows ("slots") of a fixed-shape decode state the moment capacity frees
up; finished requests release their slot mid-flight so the next queued
request starts immediately instead of waiting for the whole batch.

The loop per `step()`:

  1. ROUTE -- feed the router a load signal (queue depth + queued-token
     backlog); if it picks a different precision tier, swap the served
     params from the tier cache (O(1) after first materialization).
     Dequantized tiers all share one pytree structure, so one jitted
     step serves them without recompiling; PACKED tiers (TierEntry.
     packed_bits set) swap the r-bit planes the kernel reads, and the
     scheduler keeps one jitted prefill/decode closure per packed
     representation key (bitwidth / per-layer bits tuple / `(key,
     "ep")` with the overflow bitmap -- see `_step_fns`) -- lazily
     compiled on the first visit, a dict lookup on every revisit, so a
     downgrade also cuts HBM weight bytes instead of only changing
     quality.
  2. ADMIT -- pop queued requests while the page pool can seat them.
     All same-step admissions are BATCHED: grouped by padded
     prompt-length bucket, each bucket runs ONE jitted
     prefill-into-slots call (per-row last_pos gathers, scatter-insert
     with dropped padding rows), so a burst of N arrivals costs
     #buckets prefill launches instead of N.
  3. DECODE -- one jitted `decode_step_slots` over the FULL slot array
     with a per-slot position vector. Shapes are static; inactive slots
     compute garbage that is ignored host-side (active-mask
     bookkeeping), and their rows are fully overwritten at the next
     admission.
  4. EVICT -- requests hitting EOS or max_new_tokens free their slot and
     pages; metrics record TTFT / latency / per-tier counters.

Both jitted closures DONATE the slot-array state (`donate_argnums`), so
prefill-insert and decode update the multi-megabyte KV buffers in place
instead of allocating a fresh copy of the whole pytree per call -- the
previous O(B)-copy admission bottleneck on bursty arrivals.

On a `(data, model)` serving mesh (`mesh=`) the whole loop runs
sharded: tier params live at the placement `engine.
served_param_shardings` resolves (packed planes shard their unpacked
dim over 'model'), the slot-array state is placed batch-over-'data' /
heads-over-'model' (`runtime.sharding.SERVE_STATE_RULES`), and each
per-representation closure compiles with explicit in/out shardings so
the donated buffers keep one layout for the life of the session. Tier
switches behave exactly as off-mesh: one compile per representation
key, a dict lookup on revisit, with `TierCache` handing over planes
already placed in sharded buffers.

Single-batch equivalence: with every request admitted at step 0 at the
same prompt length and a fixed tier, the per-slot math is identical to
the legacy fixed-batch `Engine.generate` loop (same prefill, same
per-position decode attention), so outputs are token-identical for
dense/vlm/moe -- MoE expert dispatch is row-local (per-row sort +
capacity in ffn.apply_moe), so slot garbage and admission padding ROWS
never couple into active rows. One MoE caveat remains for mixed-length
traffic: bucketed admission right-pads each prompt to its bucket, and a
row's pad tokens compete inside that row's own expert-capacity buckets
(capacity is computed from the padded length), so under a tight
capacity_factor a padded row can drop real tokens that an unpadded
prefill would have kept.
"""

from __future__ import annotations

import collections
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.serve import kv_cache, specdecode
from repro.serve.metrics import ServeMetrics
from repro.serve.router import ElasticPrecisionRouter, TierCache

_MIN_BUCKET = 8


def _bucket(n: int, cap: int) -> int:
    """Static prompt pad length: next power of two, clamped to cap."""
    b = _MIN_BUCKET
    while b < n:
        b *= 2
    return min(b, cap)


def _row_bucket(n: int) -> int:
    """Static admission-burst row count: next power of two (from 1)."""
    b = 1
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class Request:
    """One generation request.

    priority: deadline-sensitive traffic. A single scheduler treats it
    like any other request; the fleet dispatcher (serve/fleet.py)
    routes priority requests to a pinned high-bit replica so they never
    decode below int4.
    """
    uid: object
    prompt: np.ndarray                  # (S,) int32 token ids
    max_new_tokens: int
    eos_id: int | None = None
    priority: bool = False

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        assert self.prompt.size > 0 and self.max_new_tokens > 0


@dataclasses.dataclass
class _Active:
    req: Request
    generated: list[int]
    last_token: int


def poisson_trace(cfg, *, requests: int, prompt_len: int, gen_tokens: int,
                  rate: float, seed: int = 0):
    """Synthetic open-loop workload: (offset_seconds, Request) pairs with
    exponential inter-arrivals, shared by the serve driver and the
    throughput benchmark so both replay the same trace."""
    from repro.data import DataConfig, SyntheticCorpus
    # the prompt corpus derives from the SAME seed as the arrival
    # offsets, so one --seed pins the whole trace (bit-reproducible
    # replays; seed=0 keeps the historical corpus seed 123)
    corpus = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=prompt_len, seed=123 + seed))
    prompts = np.asarray(corpus.batch(0, requests, prompt_len)["tokens"])
    rng = np.random.default_rng(seed)
    offsets = np.cumsum(rng.exponential(1.0 / rate, size=requests))
    return [(float(t), Request(uid=i, prompt=prompts[i],
                               max_new_tokens=gen_tokens))
            for i, t in enumerate(offsets)]


def shared_prefix_trace(cfg, *, requests: int, prefix_len: int,
                        suffix_len: int, gen_tokens: int, rate: float,
                        seed: int = 0):
    """Shared-system-prompt workload: every request's prompt is one
    common `prefix_len`-token prefix followed by its own
    `suffix_len`-token suffix -- the chatbot trace the prefix cache
    exists for. Arrival offsets are exponential like `poisson_trace`;
    the first request is always a cold miss, every later one a prefix
    hit, so a replay measures hit-rate and hit-vs-cold TTFT directly."""
    from repro.data import DataConfig, SyntheticCorpus
    plen = prefix_len + suffix_len
    corpus = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=plen, seed=123 + seed))
    rows = np.asarray(corpus.batch(0, requests, plen)["tokens"])
    shared = rows[0, :prefix_len]
    prompts = np.concatenate(
        [np.broadcast_to(shared, (requests, prefix_len)),
         rows[:, prefix_len:]], axis=1)
    rng = np.random.default_rng(seed)
    offsets = np.cumsum(rng.exponential(1.0 / rate, size=requests))
    return [(float(t), Request(uid=i, prompt=prompts[i],
                               max_new_tokens=gen_tokens))
            for i, t in enumerate(offsets)]


class ContinuousBatchingScheduler:
    """Slot-array continuous batching over one model's decode state.

    params: served params for the fixed tier, OR None with `router` +
      `tier_cache` set for elastic-precision serving (dequantized or
      packed tiers; see TierCache).
    num_slots: decode batch dimension (concurrent requests).
    max_len: token capacity per slot (prompt + generation); rounded up
      to whole pages.
    total_pages: optional global page budget (overcommit; see PagePool).
    clock: float-returning time source (injectable for tests).
    mesh: optional `(data, model)` serving mesh. The slot-array decode
      state is placed batch-over-'data' / heads-over-'model'
      (`runtime.sharding.SERVE_STATE_RULES`) and every per-
      representation step closure compiles with explicit
      in_shardings/out_shardings (params at their tier's placement,
      state at the slot placement, scalar-ish operands replicated), so
      a tier switch on the mesh keeps the one-compile-per-key
      guarantee and the donated KV buffers never change layout.
    param_shardings: NamedSharding tree of `params` (fixed-tier path
      on a mesh; elastic tiers carry theirs in `TierEntry.shardings`).
    """

    def __init__(self, params, cfg, *, num_slots: int = 8,
                 max_len: int = 128, page_size: int = 16,
                 total_pages: int | None = None,
                 kv: kv_cache.KVCacheConfig | None = None,
                 router: ElasticPrecisionRouter | None = None,
                 tier_cache: TierCache | None = None,
                 tier=None,
                 packed_bits=None,
                 spec_decode: specdecode.SpecDecodeConfig | None = None,
                 draft_source=None,
                 mesh=None, param_shardings=None,
                 clock=time.perf_counter):
        if cfg.family not in ("dense", "vlm", "moe"):
            raise NotImplementedError(
                f"continuous batching needs an attention KV cache; "
                f"family {cfg.family!r} is not slot-addressable")
        # MoE is safe here: expert dispatch is ROW-LOCAL (per-row sort +
        # capacity in ffn.apply_moe), so garbage tokens in free slots and
        # padding rows of a batched admission never perturb other rows'
        # routing. Only intra-row prompt padding can shift a row's own
        # capacity buckets, and only when capacity_factor is tight.
        if router is not None and tier_cache is None:
            raise ValueError("router requires a tier_cache")
        self.cfg = cfg
        self.clock = clock
        self.router = router
        self.tier_cache = tier_cache
        self.mesh = mesh
        self.metrics = ServeMetrics()
        self.kv = kv
        if kv is not None and kv.page_size:
            page_size = kv.page_size
        draft_len = spec_decode.draft_len if spec_decode else 0
        if kv is None:
            self.pool = kv_cache.PagePool(
                num_slots, page_size,
                pages_per_slot=-(-max_len // page_size),
                total_pages=total_pages)
            self.capacity = self.pool.slot_capacity
        else:
            # paged mode: the slot's token capacity stays max_len rounded
            # to whole pages; spec-decode draft headroom rides in extra
            # page columns so a verify block always has reserved rows.
            self.capacity = page_size * (-(-max_len // page_size))
            pages_per_slot = -(-(self.capacity + draft_len) // page_size)
            self.pool = kv_cache.PagedPool(
                num_slots, page_size, pages_per_slot=pages_per_slot,
                total_pages=total_pages, prefix_cache=kv.prefix_cache)
        self.num_slots = num_slots
        self.spec = spec_decode
        self._draft_source = draft_source
        self._draft_params: dict[str, object] = {}
        # spec decode scratch headroom: a verify step block-writes up to
        # draft_len KV rows past a slot's last committed position, and
        # `dynamic_update_slice` CLAMPS start indices -- without the
        # headroom a near-capacity verify would silently shift its
        # writes onto live rows. Admission capacity stays `capacity`;
        # only the cache rows grow.
        self.cache_len = self.capacity + (spec_decode.draft_len
                                          if spec_decode else 0)
        # one (prefill, decode) jitted closure pair per served weight
        # representation: key = packed bitwidth (int), a per-layer bits
        # tuple (packed Mix'n'Match), or None for dequantized params.
        # Lazily built, kept across reset().
        self._fns: dict[object, dict] = {}
        self.prefill_calls = 0          # jitted prefill launches (O(#buckets)
                                        # per admission burst, not O(N))
        if router is not None:
            self._set_tier(router.tier)
        elif tier_cache is not None:
            # fleet-managed elastic mode: no local router -- an external
            # policy (serve/fleet.py's FleetRouter) owns the tier and
            # drives it through `set_tier`; `tier` seeds the initial one
            assert tier is not None, "tier_cache without router needs tier="
            self._set_tier(tier)
        else:
            assert params is not None
            self.tier = None
            self.params = params
            self.packed_bits = (packed_bits if packed_bits is not None
                                else cfg.quant.packed_bits or None)
            self._param_shardings = param_shardings
        if kv is None:
            self.state = api.init_state(cfg, num_slots, self.cache_len)
            state_axes = api.state_axes(cfg)
            self._ptab = None
        else:
            self.state = api.init_paged_state(
                cfg, self.pool.total_pages, page_size,
                kv_bits=(8 if kv.quantized else None))
            state_axes = api.paged_state_axes(cfg, kv_bits=kv.kv_bits
                                              if kv.quantized else None)
            self._ptab = self.pool.page_table()
            self._copy_fn = jax.jit(kv_cache.copy_pages, donate_argnums=(0,))
            self.metrics.on_kv_config(
                bytes_per_token=kv.bytes_per_token(cfg),
                kv_bits=kv.kv_bits, prefix_cache=kv.prefix_cache,
                resident_bytes_per_token=kv.resident_bytes_per_token(cfg),
                bytes_read_per_token=kv.bytes_read_per_token(cfg),
                attn_kernel=kv.attn_kernel)
        if mesh is not None:
            from repro.runtime import sharding as shard_lib
            self._state_shardings = shard_lib.tree_shardings(
                state_axes, self.state, mesh,
                rules=shard_lib.SERVE_STATE_RULES)
            self.state = jax.device_put(self.state, self._state_shardings)
        else:
            self._state_shardings = None
        self.pos = np.zeros((num_slots,), np.int32)
        self.queue: collections.deque[Request] = collections.deque()
        self.active: dict[int, _Active] = {}
        self.results: dict[object, np.ndarray] = {}
        self._batch_axes = kv_cache.state_batch_axes(cfg)
        self._seq_axes = kv_cache.state_seq_axes(cfg)

    # -- per-representation compiled closures -------------------------------

    def _step_fns(self, key) -> dict:
        """(prefill, decode) jitted closures for one weight representation.

        WHY a keyed cache exists at all: packed tiers differ in pytree
        STRUCTURE, not just values. Every `core.packing.PackedPlane`
        carries (bits, pack_axis, extra_precision) as static aux data,
        so two tiers' params have different treedefs and a single
        jitted closure cannot serve both -- XLA would need a retrace
        anyway, and tracing through the wrong closure would misread the
        packed words. Keying one closure pair per representation turns
        that forced retrace into: compile once on the FIRST visit of a
        representation, dict lookup on every revisit (the no-recompile-
        on-revisit guarantee the tier-switch tests pin down).

        `key` is `core.packing.packed_rep_key` of the tier serving right
        now (== `TierEntry.packed_bits`):

          * int          -- uniform packed tier (e.g. 4);
          * tuple[int]   -- packed Mix'n'Match tier, the per-layer bits
                            (layers are unstacked lists of planes, each
                            with its own static r);
          * (key, "ep")  -- extra-precision variant of either: every
                            plane additionally carries the 1-bit
                            overflow bitmap leaf, a different treedef
                            from the plain tier at the same bits;
          * None         -- dequantized params. ALL dequantized tiers
                            share one pytree structure and dtype, so
                            this single closure serves every one of
                            them with no retrace on a switch.

        The closure never reads cfg.quant.packed_bits at trace time
        (PackedPlane is self-describing); `_rep_cfg` keeps the field
        coherent with the representation being served for config
        introspection only.
        """
        if self.kv is not None:
            return self._paged_step_fns(key)
        fns = self._fns.get(key)
        if fns is not None:
            return fns
        cfg = self._rep_cfg(key)
        cache_len, batch_axes = self.cache_len, self._batch_axes

        state_shardings = self._state_shardings

        def prefill(p, st, toks, slots, lengths):
            logits, slot_state = api.prefill(
                p, {"tokens": toks}, cfg, bits=None, max_len=cache_len,
                last_pos=lengths)
            st = kv_cache.insert_slots(st, slot_state, slots, batch_axes,
                                       shardings=state_shardings)
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), st

        def decode(p, st, tok, pos):
            logits, st = api.decode_step_slots(p, st, tok, pos, cfg, bits=None)
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), st

        # donate the slot-array state: both closures overwrite it
        # wholesale, so the KV buffers are updated in place instead of
        # copied per call. prefill retraces once per (rows, prompt)
        # bucket shape; decode compiles once per representation.
        if self.mesh is not None:
            # explicit shardings on the mesh: params at their tier's
            # placement (captured NOW -- _set_tier updates it before any
            # step of a new representation, and every tier sharing a
            # representation resolves equal shardings, so revisits hit
            # the jit cache), state at the slot-array placement, token/
            # position vectors replicated. Pinning the state OUTPUT
            # sharding keeps the donated KV buffers layout-stable.
            from jax.sharding import NamedSharding, PartitionSpec
            rep = NamedSharding(self.mesh, PartitionSpec())
            ps, ss = self._param_shardings, state_shardings
            fns = {"prefill": jax.jit(prefill, donate_argnums=(1,),
                                      in_shardings=(ps, ss, rep, rep, rep),
                                      out_shardings=(rep, ss)),
                   "decode": jax.jit(decode, donate_argnums=(1,),
                                     in_shardings=(ps, ss, rep, rep),
                                     out_shardings=(rep, ss))}
        else:
            fns = {"prefill": jax.jit(prefill, donate_argnums=(1,)),
                   "decode": jax.jit(decode, donate_argnums=(1,))}
        self._fns[key] = fns
        return fns

    def _paged_step_fns(self, key) -> dict:
        """(prefill, prefill_hit, decode) closures for one (weight
        representation, KV attend width) pair -- the paged twin of
        `_step_fns`. The KV attend width joins the cache key because the
        Matryoshka slice shift is STATIC in the attend graph: under
        `kv_bits="auto"` a weight-tier switch also reslices the KV read
        view, landing on its own compiled closure (first visit compiles,
        revisits are dict lookups, exactly like packed weight tiers)."""
        kvb = self.kv.attend_bits(key)
        fkey = (key, "kv", kvb)
        fns = self._fns.get(fkey)
        if fns is not None:
            return fns
        cfg = self._rep_cfg(key)
        state_shardings = self._state_shardings
        # engine-static attend path: "fused" (Pallas off the page store)
        # or "gather" -- never changes mid-engine, so it does NOT join
        # fkey; the one-compile-per-(rep, "kv", kv_bits) contract holds.
        ak = self.kv.attn_kernel

        def prefill(p, st, toks, ptab, lengths):
            logits, st = api.prefill_paged(
                p, {"tokens": toks}, cfg, st, ptab, bits=None,
                last_pos=lengths, kv_bits=kvb)
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), st

        def prefill_hit(p, st, toks, ptab, lengths, start):
            logits, st = api.prefill_paged(
                p, {"tokens": toks}, cfg, st, ptab, bits=None,
                last_pos=lengths, start=start, kv_bits=kvb)
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), st

        def decode(p, st, tok, pos, ptab):
            logits, st = api.decode_step_slots(p, st, tok, pos, cfg,
                                               bits=None, ptab=ptab,
                                               kv_bits=kvb, attn_kernel=ak)
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), st

        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            rep = NamedSharding(self.mesh, PartitionSpec())
            ps, ss = self._param_shardings, state_shardings
            fns = {"prefill": jax.jit(prefill, donate_argnums=(1,),
                                      in_shardings=(ps, ss, rep, rep, rep),
                                      out_shardings=(rep, ss)),
                   "prefill_hit": jax.jit(prefill_hit, donate_argnums=(1,),
                                          in_shardings=(ps, ss, rep, rep,
                                                        rep, rep),
                                          out_shardings=(rep, ss)),
                   "decode": jax.jit(decode, donate_argnums=(1,),
                                     in_shardings=(ps, ss, rep, rep, rep),
                                     out_shardings=(rep, ss))}
        else:
            fns = {"prefill": jax.jit(prefill, donate_argnums=(1,)),
                   "prefill_hit": jax.jit(prefill_hit, donate_argnums=(1,)),
                   "decode": jax.jit(decode, donate_argnums=(1,))}
        self._fns[fkey] = fns
        return fns

    def _rep_cfg(self, key):
        """cfg with quant adjusted for one representation key (the
        closure-trace config: PackedPlane is self-describing, so
        packed_bits is introspection-only bookkeeping, and the Pallas
        kernel turns on where it compiles)."""
        cfg = self.cfg
        if key:
            qc = dataclasses.replace(
                cfg.quant,
                packed_bits=key if isinstance(key, int) else 0,
                # the Pallas kernel where it compiles; jnp twin elsewhere
                packed_kernel=(cfg.quant.packed_kernel
                               or jax.default_backend() == "tpu"))
        else:
            qc = dataclasses.replace(cfg.quant, packed_bits=0)
        return cfg.replace(quant=qc)

    def _spec_draft(self):
        """Draft params for the CURRENT tier (cached per tier name).

        Packed tiers alias their resident planes (`sliced_view`, zero
        extra plane bytes); the dequantized fallback materializes from
        the float parent (`draft_source`, or the tier cache's parent).
        """
        name = self.tier_name
        dp = self._draft_params.get(name)
        if dp is None:
            parent = self._draft_source
            if parent is None and self.tier_cache is not None:
                parent = self.tier_cache.parent_params
            dp = specdecode.draft_params_for(self.params, self.cfg,
                                             self.spec, parent_params=parent)
            if self.mesh is not None:
                from repro.serve.engine import served_param_shardings
                sh = served_param_shardings(dp, self.cfg, self.mesh)
                # aliased leaves are already placed; device_put is a
                # no-op for them and places only the new alpha rescales
                dp = jax.device_put(dp, sh)
                dp = (dp, sh)
            else:
                dp = (dp, None)
            self._draft_params[name] = dp
        return dp

    def _spec_fns(self, draft_shardings) -> dict:
        """(draft, verify) jitted closures for one (draft, verify)
        representation pair -- same keyed-cache contract as
        `_step_fns`: the draft view's treedef differs per (slice width,
        resident representation), so each pair compiles exactly once
        and is a dict lookup on every revisit.

        The verify closure folds greedy acceptance AND the KV rollback
        into the jitted step: it returns (verify_pred (B, T), accepted
        prefix length m (B,), state with rows >= pos + m + 1 cleared).
        """
        paged = self.kv is not None
        kvb = self.kv.attend_bits(self.packed_bits) if paged else None
        key = specdecode.spec_fns_key(self.spec.draft_key, self.packed_bits)
        if paged:
            key = (key, "kv", kvb)
        fns = self._fns.get(key)
        if fns is not None:
            return fns
        cfg = self._rep_cfg(self.packed_bits)
        batch_axes, seq_axes = self._batch_axes, self._seq_axes
        state_shardings = self._state_shardings

        def draft(p, st, tok, pos):
            logits, st = api.decode_step_slots(p, st, tok, pos, cfg, bits=None)
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), st

        def verify(p, st, toks, pos):
            logits, st = api.verify_step_slots(p, st, toks, pos, cfg,
                                               bits=None)
            pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B, T)
            match = (toks[:, 1:] == pred[:, :-1]).astype(jnp.int32)
            m = jnp.cumprod(match, axis=1).sum(axis=1)             # (B,)
            st = kv_cache.rollback_slots(st, pos + m + 1, batch_axes,
                                         seq_axes)
            return pred, m, st

        ak = self.kv.attn_kernel if paged else None

        def draft_paged(p, st, tok, pos, ptab):
            logits, st = api.decode_step_slots(p, st, tok, pos, cfg,
                                               bits=None, ptab=ptab,
                                               kv_bits=kvb, attn_kernel=ak)
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), st

        def verify_paged(p, st, toks, pos, ptab):
            # no rollback scrub: stale draft rows past the accepted
            # prefix stay masked (ki <= pos) until the next write lands
            # on the same (page, row) -- the paged rewind is free.
            logits, st = api.verify_step_slots(p, st, toks, pos, cfg,
                                               bits=None, ptab=ptab,
                                               kv_bits=kvb)
            pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B, T)
            match = (toks[:, 1:] == pred[:, :-1]).astype(jnp.int32)
            m = jnp.cumprod(match, axis=1).sum(axis=1)             # (B,)
            return pred, m, st

        if paged:
            draft, verify = draft_paged, verify_paged
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            rep = NamedSharding(self.mesh, PartitionSpec())
            ps, ss = self._param_shardings, state_shardings
            extra = (rep,) if paged else ()
            fns = {"draft": jax.jit(draft, donate_argnums=(1,),
                                    in_shardings=(draft_shardings, ss, rep,
                                                  rep) + extra,
                                    out_shardings=(rep, ss)),
                   "verify": jax.jit(verify, donate_argnums=(1,),
                                     in_shardings=(ps, ss, rep, rep) + extra,
                                     out_shardings=(rep, rep, ss))}
        else:
            fns = {"draft": jax.jit(draft, donate_argnums=(1,)),
                   "verify": jax.jit(verify, donate_argnums=(1,))}
        self._fns[key] = fns
        return fns

    def _set_tier(self, tier):
        """Swap the served params to `tier` (cache lookup after first use)."""
        entry = self.tier_cache.get(tier)
        self.tier = tier
        self.params = entry.params
        self.packed_bits = entry.packed_bits
        self._param_shardings = entry.shardings
        self.metrics.on_tier_bytes(
            tier.name, packed_bits=entry.packed_bits,
            packed_nbytes=entry.packed_nbytes,
            weight_nbytes=entry.weight_nbytes,
            effective_bits=entry.effective_bits,
            per_device_plane_nbytes=entry.per_device_plane_nbytes)

    def set_tier(self, tier):
        """Externally swap the served tier (fleet-managed elastic mode).

        The cache lookup + param swap is `_set_tier`; this public entry
        exists for callers OUTSIDE the scheduler's own routing loop --
        the fleet's global router assigns each replica its tier and
        pushes it here between two steps. No-op when the tier is
        already serving (revisits stay dict lookups + jit-cache hits).
        """
        if self.tier_cache is None:
            raise ValueError("set_tier needs elastic serving (tier_cache); "
                             "this scheduler serves a fixed tier")
        if self.tier is None or tier.name != self.tier.name:
            self._set_tier(tier)

    def drain_requests(self) -> list[Request]:
        """Evacuate every queued AND in-flight request for requeueing.

        The fleet calls this when a replica must stop serving (it is
        being retired, or a sibling's failure handling rehearses on a
        live scheduler): slots and pages are freed, and the ORIGINAL
        Request objects come back -- partial generations are discarded,
        which is safe because greedy decode is deterministic, so a
        fresh replay on a survivor reproduces the identical tokens.
        Finished results already harvested are untouched.
        """
        out = [self.active[slot].req for slot in sorted(self.active)]
        for slot in list(self.active):
            self.active.pop(slot)
            self.pool.free(slot)
            self.pos[slot] = 0
        out += list(self.queue)
        self.queue.clear()
        return out

    def reset(self):
        """Clear all requests/bookkeeping but keep the compiled closures.

        Slot rows need no zeroing: every admission overwrites its whole
        row via prefill-into-slot.
        """
        pool = self.pool
        if self.kv is not None:
            self.pool = kv_cache.PagedPool(
                pool.num_slots, pool.page_size,
                pages_per_slot=pool.pages_per_slot,
                total_pages=pool.total_pages,
                prefix_cache=pool.prefix_cache)
            self._ptab = self.pool.page_table()
        else:
            self.pool = kv_cache.PagePool(pool.num_slots, pool.page_size,
                                          pages_per_slot=pool.pages_per_slot,
                                          total_pages=pool.total_pages)
        self.pos[:] = 0
        self.queue.clear()
        self.active.clear()
        self.results = {}
        self.metrics = ServeMetrics()
        if self.kv is not None:
            self.metrics.on_kv_config(
                bytes_per_token=self.kv.bytes_per_token(self.cfg),
                kv_bits=self.kv.kv_bits, prefix_cache=self.kv.prefix_cache,
                resident_bytes_per_token=self.kv.resident_bytes_per_token(
                    self.cfg),
                bytes_read_per_token=self.kv.bytes_read_per_token(self.cfg),
                attn_kernel=self.kv.attn_kernel)
        self.prefill_calls = 0
        if self.router is not None:
            self.router.reset()
            self._set_tier(self.router.tier)

    # -- request intake ----------------------------------------------------

    def submit(self, req: Request, now: float | None = None):
        total = req.prompt.size + req.max_new_tokens
        if total > self.capacity:
            raise ValueError(
                f"request {req.uid!r} needs {total} tokens; slot capacity "
                f"is {self.capacity} (raise max_len)")
        if self.pool.pages_for(total) > self.pool.total_pages:
            raise ValueError(
                f"request {req.uid!r} needs {self.pool.pages_for(total)} "
                f"pages; the pool budget is {self.pool.total_pages} -- it "
                f"could never be admitted")
        now = self.clock() if now is None else now
        self.metrics.on_submit(req.uid, now, req.prompt.size)
        self.queue.append(req)

    # -- scheduling loop ---------------------------------------------------

    @property
    def tier_name(self) -> str:
        return self.tier.name if self.tier is not None else "fixed"

    def load_signal(self) -> float:
        backlog = sum(r.prompt.size + r.max_new_tokens for r in self.queue)
        return len(self.queue) + backlog / self.capacity

    def _route(self):
        if self.router is None:
            return
        tier = self.router.observe(self.load_signal())
        if tier.name != self.tier.name:
            self._set_tier(tier)

    def _admit(self, now: float) -> int:
        if self.kv is not None:
            return self._admit_paged(now)
        # pop everything the pool can seat right now ...
        picked: list[tuple[Request, int]] = []
        while self.queue:
            req = self.queue[0]
            total = req.prompt.size + req.max_new_tokens
            slot = self.pool.allocate(req.uid, total)
            if slot is None:
                break
            self.queue.popleft()
            picked.append((req, slot))
        if not picked:
            return 0
        # ... then seat the whole burst with ONE prefill per prompt
        # bucket: rows padded to a static power-of-two count, padding
        # rows targeting slot id == num_slots (dropped by the scatter).
        prefill_fn = self._step_fns(self.packed_bits)["prefill"]
        buckets: dict[int, list[tuple[Request, int]]] = {}
        for req, slot in picked:
            buckets.setdefault(_bucket(req.prompt.size, self.capacity),
                               []).append((req, slot))
        for P, group in sorted(buckets.items()):
            rows = _row_bucket(len(group))
            toks = np.zeros((rows, P), np.int32)
            slots = np.full((rows,), self.num_slots, np.int32)
            lengths = np.ones((rows,), np.int32)
            for i, (req, slot) in enumerate(group):
                plen = req.prompt.size
                toks[i, :plen] = req.prompt
                slots[i] = slot
                lengths[i] = plen
            first, self.state = prefill_fn(
                self.params, self.state, jnp.asarray(toks),
                jnp.asarray(slots), jnp.asarray(lengths))
            self.prefill_calls += 1
            first = np.asarray(first)           # forces the computation
            t_tok = self.clock()
            for i, (req, slot) in enumerate(group):
                tok = int(first[i])
                plen = req.prompt.size
                self.pos[slot] = plen
                self.active[slot] = _Active(req=req, generated=[tok],
                                            last_token=tok)
                self.pool.grow(slot, plen + 1)
                self.metrics.on_admit(req.uid, now, self.tier_name)
                self.metrics.on_first_token(req.uid, t_tok)
                if req.max_new_tokens == 1 or tok == req.eos_id:
                    self._finish(slot, t_tok)
        return len(picked)

    def _admit_paged(self, now: float) -> int:
        """Paged admission: prefix-match + reserve pages, apply COW
        copies, then one prefill per (bucket, cold/hit) group.

        Cold admissions run the exact dense prefill graph over the full
        prompt; prefix hits prefill ONLY the suffix past their shared
        length (the TTFT win), bucketed separately so suffix shapes stay
        static. Spec-decode draft headroom is reserved up front, so a
        verify block never writes an unreserved page."""
        draft_len = self.spec.draft_len if self.spec else 0
        picked: list[tuple[Request, int, int]] = []
        cow_src: list[int] = []
        cow_dst: list[int] = []
        while self.queue:
            req = self.queue[0]
            total = req.prompt.size + req.max_new_tokens + draft_len
            got = self.pool.admit(req.uid, req.prompt, total)
            if got is None:
                break
            slot, shared_len, cow = got
            self.queue.popleft()
            picked.append((req, slot, shared_len))
            for s, d in cow:
                cow_src.append(s)
                cow_dst.append(d)
        if not picked:
            return 0
        self._ptab = self.pool.page_table()
        if cow_src:
            # pad the copy list to a static bucket (sentinel pairs are
            # dropped) so the jitted COW retraces per bucket size only
            n = _row_bucket(len(cow_src))
            hole = self.pool.total_pages
            src = np.full((n,), hole, np.int32)
            dst = np.full((n,), hole, np.int32)
            src[:len(cow_src)] = cow_src
            dst[:len(cow_dst)] = cow_dst
            self.state = self._copy_fn(self.state, jnp.asarray(src),
                                       jnp.asarray(dst))
        fns = self._step_fns(self.packed_bits)
        buckets: dict[tuple[int, bool], list[tuple[Request, int, int]]] = {}
        for req, slot, shared in picked:
            hit = shared > 0
            plen = req.prompt.size - shared
            buckets.setdefault((_bucket(plen, self.capacity), hit),
                               []).append((req, slot, shared))
        for (P, hit), group in sorted(buckets.items()):
            rows = _row_bucket(len(group))
            toks = np.zeros((rows, P), np.int32)
            lengths = np.ones((rows,), np.int32)
            start = np.zeros((rows,), np.int32)
            ptab = np.full((rows, self.pool.pages_per_slot),
                           self.pool.total_pages, np.int32)
            slots = []
            for i, (req, slot, shared) in enumerate(group):
                suffix = req.prompt[shared:]
                toks[i, :suffix.size] = suffix
                lengths[i] = suffix.size
                start[i] = shared
                ptab[i] = self._ptab[slot]
                slots.append(slot)
            if hit:
                first, self.state = fns["prefill_hit"](
                    self.params, self.state, jnp.asarray(toks),
                    jnp.asarray(ptab), jnp.asarray(lengths),
                    jnp.asarray(start))
            else:
                first, self.state = fns["prefill"](
                    self.params, self.state, jnp.asarray(toks),
                    jnp.asarray(ptab), jnp.asarray(lengths))
            self.prefill_calls += 1
            first = np.asarray(first)           # forces the computation
            t_tok = self.clock()
            for i, (req, slot, shared) in enumerate(group):
                tok = int(first[i])
                plen = req.prompt.size
                self.pos[slot] = plen
                self.active[slot] = _Active(req=req, generated=[tok],
                                            last_token=tok)
                self.pool.grow(slot, plen + 1)
                self.pool.register_prefix(slot, req.prompt)
                self.metrics.on_admit(req.uid, now, self.tier_name)
                self.metrics.on_admit_kv(req.uid, plen, shared)
                self.metrics.on_first_token(req.uid, t_tok)
                if req.max_new_tokens == 1 or tok == req.eos_id:
                    self._finish(slot, t_tok)
        return len(picked)

    def _finish(self, slot: int, now: float):
        act = self.active.pop(slot)
        self.pool.free(slot)
        self.pos[slot] = 0
        self.results[act.req.uid] = np.asarray(act.generated, np.int32)
        self.metrics.on_finish(act.req.uid, now, len(act.generated))

    def step(self, now: float | None = None) -> bool:
        """One scheduler iteration; returns True if any work was done."""
        now = self.clock() if now is None else now
        self._route()
        admitted = self._admit(now)
        decoded = 0
        if self.active and self.spec is not None:
            decoded = self._spec_round()
        elif self.active:
            toks = np.zeros((self.num_slots, 1), np.int32)
            for slot, act in self.active.items():
                toks[slot, 0] = act.last_token
            decode_fn = self._step_fns(self.packed_bits)["decode"]
            args = (self.params, self.state, jnp.asarray(toks),
                    jnp.asarray(self.pos))
            if self.kv is not None:
                args = args + (jnp.asarray(self._ptab),)
            next_toks, self.state = decode_fn(*args)
            next_toks = np.asarray(next_toks)   # forces the computation
            t_tok = self.clock()
            for slot in list(self.active):
                act = self.active[slot]
                tok = int(next_toks[slot])
                act.generated.append(tok)
                act.last_token = tok
                self.pos[slot] += 1
                self.pool.grow(slot, self.pos[slot] + 1)
                decoded += 1
                if (len(act.generated) >= act.req.max_new_tokens
                        or tok == act.req.eos_id):
                    self._finish(slot, t_tok)
        if admitted or decoded:
            self.metrics.on_step(
                self.tier_name, new_tokens=admitted + decoded,
                active=len(self.active), queue_depth=len(self.queue),
                decoded_tokens=decoded)
            if self.kv is not None:
                self.metrics.on_pages(self.pool.used_pages,
                                      self.pool.written_pages,
                                      self.pool.total_pages)
        return bool(admitted or decoded)

    def _spec_round(self) -> int:
        """One draft/verify/accept/rollback round over the slot array.

        k draft steps with the sliced plane write scratch KV at rows
        P..P+k-1, ONE verify step scores the whole block [d_0..d_k],
        overwriting those rows with the resident tier's own
        projections; greedy acceptance emits the agreeing prefix plus
        the verify model's bonus token (1..k+1 tokens per slot per
        round, all of them the resident tier's argmax -- token-exact vs
        plain decode), and the jitted verify closure clears the stale
        rows past each slot's accepted prefix. Returns tokens emitted.
        """
        k = self.spec.draft_len
        draft_p, draft_sh = self._spec_draft()
        fns = self._spec_fns(draft_sh)
        last = np.zeros((self.num_slots, 1), np.int32)
        for slot, act in self.active.items():
            last[slot, 0] = act.last_token
        pos0 = jnp.asarray(self.pos)
        cur = jnp.asarray(last)
        extra = (jnp.asarray(self._ptab),) if self.kv is not None else ()
        blocks = [cur]
        st = self.state
        for j in range(k):
            nxt, st = fns["draft"](draft_p, st, cur, pos0 + j, *extra)
            cur = nxt[:, None]
            blocks.append(cur)
        toks = jnp.concatenate(blocks, axis=1)            # (B, k+1)
        pred, m, self.state = fns["verify"](self.params, st, toks, pos0,
                                            *extra)
        pred = np.asarray(pred)                 # forces the computation
        m = np.asarray(m)
        toks = np.asarray(toks)
        t_tok = self.clock()
        decoded = 0
        for slot in list(self.active):
            act = self.active[slot]
            mm = int(m[slot])
            accepted = [int(t) for t in toks[slot, 1:mm + 1]]
            emitted = 0
            finished = False
            for tok in accepted + [int(pred[slot, mm])]:
                act.generated.append(tok)
                act.last_token = tok
                emitted += 1
                if (len(act.generated) >= act.req.max_new_tokens
                        or tok == act.req.eos_id):
                    finished = True
                    break
            self.pos[slot] += emitted
            decoded += emitted
            self.metrics.on_spec_round(self.tier_name, drafted=k,
                                       accepted=mm, emitted=emitted)
            if finished:
                self._finish(slot, t_tok)
            else:
                self.pool.grow(slot, self.pos[slot] + 1)
        return decoded

    def defrag(self):
        """Compact live slots into a dense prefix (permutes slot rows).

        In paged mode this is a pure HOST operation: the page store is
        global, slot identity lives only in the page table, so remapping
        slots touches zero device bytes."""
        perm, moves = self.pool.defrag()
        if all(moves[old] == old for old in moves):
            return moves
        if self.kv is not None:
            self._ptab = self.pool.page_table()
        else:
            self.state = kv_cache.permute_slots(self.state, perm,
                                                self._batch_axes)
        self.pos = self.pos[np.asarray(perm)]
        self.active = {moves[old]: act for old, act in self.active.items()}
        return moves

    # -- drivers -----------------------------------------------------------

    def run_until_idle(self, max_steps: int = 100_000):
        """Drain queue + active requests; returns results dict."""
        steps = 0
        while self.queue or self.active:
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("scheduler did not drain")
        return self.results

    def run_trace(self, trace, max_steps: int = 1_000_000):
        """Replay an arrival trace of (offset_seconds, Request) pairs.

        Offsets are relative to the replay start; requests become
        visible once the wall clock passes their offset (open-loop
        arrivals). Returns the results dict.
        """
        trace = sorted(trace, key=lambda it: it[0])
        t0 = self.clock()
        i = 0
        steps = 0
        virtual = False      # set once a sleep fails to advance the clock
        while i < len(trace) or self.queue or self.active:
            now = self.clock()
            while i < len(trace) and t0 + trace[i][0] <= now:
                # stamp the TRACE arrival time, not the poll time, so
                # TTFT includes queueing delay accrued inside a step
                self.submit(trace[i][1], now=t0 + trace[i][0])
                i += 1
            if not self.step() and i < len(trace):
                # idle gap before the next arrival: sleep up to it
                wait = t0 + trace[i][0] - self.clock()
                if wait > 0:
                    if not virtual:
                        time.sleep(min(wait, 0.05))
                        virtual = self.clock() <= now
                    if virtual:
                        # non-advancing clock: offsets cannot be honored;
                        # fast-forward the next arrival to "now" (keeps
                        # TTFT/latency non-negative)
                        self.submit(trace[i][1], now=self.clock())
                        i += 1
            steps += 1
            if steps > max_steps:
                raise RuntimeError("trace replay did not drain")
        return self.results
