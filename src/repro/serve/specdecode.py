"""Matryoshka self-speculative decoding: a low-bit slice drafts, the
resident tier verifies.

MatQuant's nested packed parent makes speculative decoding free at the
weight level: the int2 (or int4, or int2+ep) plane of Section 5.4's
one-parent deployment story ALIASES the bytes of the resident int8
plane, so the serving stack already holds a draft model at zero extra
plane cost -- `core.packing.sliced_view` wraps the resident
`PackedPlane`s in static slice metadata and the kernels apply the
Eq. 4/6 MSB slice on the fly after the unpack. No other quantization
scheme gets a draft model for free this way.

The per-slot draft/verify round (driven by
`serve.scheduler.ContinuousBatchingScheduler`):

  1. DRAFT  -- the sliced plane greedily decodes k tokens d_1..d_k from
     the committed last token d_0, writing scratch KV rows P..P+k-1;
  2. VERIFY -- the resident tier scores the block [d_0..d_k] (T = k+1
     positions) in ONE `models.api.verify_step_slots` call, overwriting
     rows P..P+k with its own projections;
  3. ACCEPT -- greedy acceptance keeps the longest prefix where the
     draft agreed (`accept_lengths`), emits those m tokens plus the
     verify model's own prediction at the first disagreement (the
     "bonus" token -- every round emits >= 1 verified token), and
  4. ROLLBACK -- `serve.kv_cache.rollback_slots` clears the stale rows
     past the accepted prefix.

Greedy acceptance makes the output TOKEN-EXACT vs plain verify-tier
decoding: every emitted token is the verify model's argmax given an
exactly-committed prefix, so speculation only changes how many verify
steps the sequence costs, never which tokens come out. That exactness
is the test oracle (`tests/test_specdecode.py`).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import packing


@dataclasses.dataclass(frozen=True)
class SpecDecodeConfig:
    """Self-speculative decoding knobs.

    draft_bits: the slice width of the draft plane (int; drawn from the
      SAME resident parent the verify tier serves).
    draft_extra_precision: draft from the Errata Eq. 8 ep slice (codes
      in [0, 2^r], no clamp) instead of the plain slice.
    draft_len: k, tokens drafted per round; each round costs k draft
      steps + 1 verify step and emits between 1 and k+1 tokens.
    """

    draft_bits: int = 2
    draft_extra_precision: bool = False
    draft_len: int = 4

    def __post_init__(self):
        if not isinstance(self.draft_bits, int):
            raise ValueError("draft_bits must be a uniform int slice width")
        if self.draft_len < 1:
            raise ValueError("draft_len must be >= 1")

    @property
    def draft_key(self):
        """Rep key of the draft representation (`packed_rep_key` +
        'slice' marker: an aliased view's treedef differs from a
        materialized plane's at the same bits)."""
        return ("slice", packing.packed_rep_key(self.draft_bits,
                                                self.draft_extra_precision))


def spec_fns_key(draft_key, verify_key):
    """The scheduler's closure-cache key for one (draft, verify) pair.

    Prefixed so it can never collide with a plain representation key
    (a per-layer Mix'n'Match bits tuple is also a tuple)."""
    return ("spec", draft_key, verify_key)


def _is_plane(x):
    return isinstance(x, packing.PackedPlane)


def draft_params_for(params, cfg, spec: SpecDecodeConfig, *,
                     parent_params=None):
    """Derive the draft-tier params from the serving params.

    Packed serving params (any `PackedPlane` leaves): every plane is
    replaced by its ALIASED `core.packing.sliced_view` at
    `spec.draft_bits` -- zero additional plane bytes, the paper-native
    path. Dequantized serving params carry no packed words to slice, so
    the draft weights are materialized from the float parent checkpoint
    instead (`engine.materialize_served_params`) -- same draft tokens,
    just without the aliasing (the off-TPU fallback, mirroring how the
    dequant tiers themselves are served).
    """
    leaves = jax.tree.leaves(params, is_leaf=_is_plane)
    if any(_is_plane(leaf) for leaf in leaves):
        def slice_leaf(x):
            if _is_plane(x):
                return packing.sliced_view(
                    x, spec.draft_bits,
                    extra_precision=spec.draft_extra_precision)
            return x

        return jax.tree.map(slice_leaf, params, is_leaf=_is_plane)
    if parent_params is None:
        raise ValueError(
            "dequantized serving params need the float parent checkpoint "
            "to materialize a draft tier (Engine keeps it under "
            "keep_parent=True)")
    from repro.serve.engine import materialize_served_params
    return materialize_served_params(
        parent_params, cfg, spec.draft_bits,
        extra_precision=spec.draft_extra_precision)


def accept_lengths(draft_tokens: np.ndarray,
                   verify_pred: np.ndarray) -> np.ndarray:
    """Greedy acceptance: longest agreeing prefix per slot.

    draft_tokens: (B, k+1) -- [d_0 .. d_k], d_0 the committed last
    token; verify_pred: (B, k+1) -- verify_pred[:, j] is the verify
    model's argmax AFTER d_j. Returns m (B,) in [0, k]: d_1..d_m are
    accepted (d_{j+1} == verify_pred[:, j] for all j < m) and
    verify_pred[:, m] is the bonus token, so each slot emits m+1
    verified tokens. The jitted verify closure computes the same
    quantity in-graph; this NumPy twin is the test oracle.
    """
    match = draft_tokens[:, 1:] == verify_pred[:, :-1]          # (B, k)
    return np.cumprod(match.astype(np.int64), axis=1).sum(axis=1)


def extra_plane_nbytes(draft_params, verify_params) -> int:
    """Plane bytes of the draft params NOT aliased to verify buffers.

    The "zero additional plane bytes" claim, measured by buffer
    identity: a draft `PackedPlane` whose words (and overflow) are the
    SAME array objects as some verify plane's contributes nothing;
    anything else -- materialized draft planes, or the dequant
    fallback's full 'w' arrays -- contributes its full size. Per-plane
    alpha rescales are scale vectors, not plane bytes, matching
    `engine.served_nbytes` accounting.
    """
    verify_ids = {id(leaf) for leaf in jax.tree.leaves(verify_params)}
    for plane in jax.tree.leaves(verify_params, is_leaf=_is_plane):
        if _is_plane(plane):
            verify_ids.add(id(plane.words))
            if plane.overflow is not None:
                verify_ids.add(id(plane.overflow))
    extra = 0
    for plane in jax.tree.leaves(draft_params, is_leaf=_is_plane):
        if _is_plane(plane):
            for buf in (plane.words, plane.overflow):
                if buf is not None and id(buf) not in verify_ids:
                    extra += buf.size * buf.dtype.itemsize
        elif id(plane) not in verify_ids:
            extra += plane.size * plane.dtype.itemsize
    return extra


__all__ = ["SpecDecodeConfig", "spec_fns_key", "draft_params_for",
           "accept_lengths", "extra_plane_nbytes"]
