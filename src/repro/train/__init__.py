from repro.train.qat import init_train_state, make_loss_fn, make_train_step  # noqa: F401
from repro.train import omniquant_calib  # noqa: F401
