"""OmniQuant block-wise calibration under MatQuant (Eqs. 5 + 7).

OmniQuant freezes the model weights and trains only the per-linear aux
parameters (gamma/beta clipping strengths, shift/scale equivalents) by
gradient descent on each Transformer block's L2 reconstruction error,
layer by layer, on a small calibration set. MatQuant sums that loss
over R = {8, 4, 2}. Inputs to each block are propagated from the
*full-precision* model (the paper's y'_i = F_l(W_F, X_l)).

Implemented for the dense family (the paper's setting: Gemma-2 /
Mistral); used by the Table-1/3/4/5/7 benchmarks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.matquant import recon_loss_multi
from repro.models import common as cm
from repro.models.lm import _dense_block
from repro.optim import OptConfig, adamw_init, adamw_update


def _layer_slice(layers, l):
    return jax.tree.map(lambda x: x[l], layers)


def _layer_set(layers, l, lp):
    return jax.tree.map(lambda full, new: full.at[l].set(new), layers, lp)


def _omni_mask(tree):
    """True only for leaves under an 'omni' subtree (trainable aux)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    mask = [any(getattr(k, "key", None) == "omni" for k in path)
            for path, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, mask)


def calibrate(params, cfg, calib_tokens, *, steps_per_layer: int = 50,
              lr: float = 1e-3, verbose: bool = False):
    """Calibrate OmniQuant aux params block-by-block.

    calib_tokens: (Ncal, S) int32. Returns (params with trained aux,
    per-layer final losses)."""
    assert cfg.quant.mode == "omniquant", cfg.quant.mode
    assert cfg.family == "dense", "calibration implemented for dense family"
    qcfg = cfg.quant
    B, S = calib_tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = jnp.take(params["embed"]["w"], calib_tokens, axis=0)

    opt_cfg = OptConfig(lr=lr, clip_norm=0.0, schedule="constant",
                        warmup_steps=0, total_steps=steps_per_layer)
    layer_losses = []

    def block_q(lp, xin, *, bits):
        return _dense_block(lp, xin, cfg, bits, positions, qcfg, cfg.attn_chunk)

    @jax.jit
    def calib_layer(lp, x):
        block_fp = lambda xin: _dense_block(lp, xin, cfg, None, positions,
                                            qcfg, cfg.attn_chunk)
        mask = _omni_mask(lp)
        opt = adamw_init(lp)

        def loss_fn(lp_):
            return recon_loss_multi(
                block_fp, lambda p, xi, bits: block_q(p, xi, bits=bits),
                lp_, x, qcfg)

        def step(carry, _):
            lp_, opt_ = carry
            (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(lp_)
            lp_, opt_, _ = adamw_update(lp_, g, opt_, opt_cfg, mask=mask)
            return (lp_, opt_), loss

        (lp, _), losses = jax.lax.scan(step, (lp, opt), None,
                                       length=steps_per_layer)
        # propagate the FP output to the next block (paper semantics)
        x_next = block_fp(x)
        return lp, x_next, losses[-1]

    layers = params["layers"]
    for l in range(cfg.num_layers):
        lp = _layer_slice(layers, l)
        lp, x, final_loss = calib_layer(lp, x)
        layers = _layer_set(layers, l, lp)
        layer_losses.append(float(final_loss))
        if verbose:
            print(f"  omniquant layer {l}: recon={final_loss:.3e}")
    params = dict(params)
    params["layers"] = layers
    return params, layer_losses
