"""MatQuant QAT training step (Eq. 7 end-to-end loss, STE, AdamW).

The factory builds a pure `train_step(params, opt_state, batch)`
suitable for jax.jit with shardings. Features:
  * joint multi-precision loss over cfg.quant.bitwidths (+ optional
    co-distillation edges),
  * gradient accumulation over microbatches (lax.scan -- bounds the
    live activation set for the 4k x 256 training cells),
  * optional EF-int8 compressed cross-pod gradient psum (shard_map over
    the 'pod' axis; see repro.runtime.compression).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.matquant import matquant_loss
from repro.models import api
from repro.optim import OptConfig, adamw_init, adamw_update


def make_loss_fn(cfg, vmap_precisions: bool = False):
    """(params, batch) -> (loss, metrics); MoE aux folded in once.

    vmap_precisions=True batches the |R| per-precision forwards into ONE
    vmapped forward over the bit-width axis. Because the weights carry
    no batch dim, the int8 *parent* quantization (minmax, round, clamp)
    is computed once and shared -- only the MSB slice varies per lane --
    and every activation collective is issued once at 3x payload instead
    of 3 times (fewer launches on the wire). This is the jnp realization
    of the fused_quantize kernel's sharing, found in §Perf cell C.
    """

    def loss_fn_vmapped(params, batch):
        from repro.core.matquant import cross_entropy, soft_ce
        qcfg = cfg.quant
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        needed = sorted(set(qcfg.bitwidths) |
                        {b for e in qcfg.codistill for b in e}, reverse=True)
        bits_arr = jnp.asarray(needed, jnp.int32)

        def fwd(r):
            return api.forward(params, batch, cfg, bits=r)

        logits_all, aux_all = jax.vmap(fwd)(bits_arr)
        by_bits = {b: logits_all[i] for i, b in enumerate(needed)}
        total = jnp.float32(0.0)
        metrics = {}
        for r, lam in zip(qcfg.bitwidths, qcfg.weights):
            l_r = cross_entropy(by_bits[r], labels, mask)
            metrics[f"ce_int{r}"] = l_r
            total = total + lam * l_r
        for t, s in qcfg.codistill:
            l_d = soft_ce(by_bits[s], by_bits[t], mask)
            metrics[f"distill_{t}to{s}"] = l_d
            total = total + qcfg.codistill_alpha * qcfg.lambdas.get(s, 1.0) * l_d
        if cfg.family == "moe":
            moe_aux = 0.01 * jnp.mean(aux_all)
            metrics["moe_aux"] = moe_aux
            total = total + moe_aux
        metrics["loss"] = total
        return total, metrics

    def loss_fn(params, batch):
        aux_box = []

        def forward(params, batch, *, bits):
            logits, aux = api.forward(params, batch, cfg, bits=bits)
            aux_box.append(aux)
            return logits

        total, metrics = matquant_loss(forward, params, batch, cfg.quant)
        if aux_box and cfg.family == "moe":
            moe_aux = 0.01 * sum(aux_box) / len(aux_box)
            metrics["moe_aux"] = moe_aux
            total = total + moe_aux
            metrics["loss"] = total
        return total, metrics

    return loss_fn_vmapped if vmap_precisions else loss_fn


def make_train_step(cfg, opt_cfg: OptConfig, *, microbatches: int = 1,
                    param_mask=None, grad_compression: int = 0,
                    donate: bool = True, vmap_precisions: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    grad_compression > 0 enables EF-int8 psum across the 'pod' axis;
    the EF buffer then lives inside opt_state['ef'].
    """
    loss_fn = make_loss_fn(cfg, vmap_precisions=vmap_precisions)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return grads, metrics
        B = batch["tokens"].shape[0]
        assert B % microbatches == 0, (B, microbatches)
        mb = jax.tree.map(
            lambda x: x.reshape((microbatches, B // microbatches) + x.shape[1:]),
            batch,
        )
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, mbatch):
            (loss, metrics), g = grad_fn(params, mbatch)
            acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
            return acc, metrics

        grads, metrics = jax.lax.scan(body, zero, mb)
        grads = jax.tree.map(lambda g: (g / microbatches), grads)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return grads, metrics

    def train_step(params, opt_state, batch):
        grads, metrics = compute_grads(params, batch)
        ef = opt_state.get("ef")
        if grad_compression and ef is not None:
            from repro.runtime.compression import compress_decompress
            grads, ef = compress_decompress(grads, ef, bits=grad_compression)
        new_params, new_opt, om = adamw_update(
            params, grads, {k: v for k, v in opt_state.items() if k != "ef"},
            opt_cfg, mask=param_mask,
        )
        if ef is not None:
            new_opt["ef"] = ef
        metrics.update(om)
        return new_params, new_opt, metrics

    return train_step


def init_train_state(key, cfg, opt_cfg: OptConfig, *, grad_compression: int = 0):
    params = api.init(key, cfg)
    opt_state = adamw_init(params)
    if grad_compression:
        opt_state["ef"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    return params, opt_state
