import os
import sys

# tests must see exactly 1 CPU device (the dry-run subprocess sets its
# own XLA_FLAGS); make `pytest tests/` work without PYTHONPATH too.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
