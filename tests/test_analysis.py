"""matlint (tools.analysis): per-rule pass/fail fixtures + src/ clean.

Each rule family gets at least one snippet that must pass and one that
must fail (the failing snippets are distilled from the real bug each
rule exists to catch); the self-check at the bottom pins the actual
tree to zero findings under the committed allowlist, so a contract
regression anywhere in src/repro/ fails THIS test even before the CI
`analyze` lane runs. Pure stdlib -- no jax import anywhere in the
analyzer, so these tests stay in the fast tier-1 lane.
"""

import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))

from tools.analysis import (DEFAULT_ALLOWLIST, RULE_IDS, RULES,  # noqa: E402
                            analyze_sources, collect_files, load_allowlist)

SERVE = "src/repro/serve/_fixture.py"     # synthetic scope-carrying paths
MODELS = "src/repro/models/_fixture.py"


def run(src, rel=SERVE, allowlist=frozenset()):
    findings, suppressed = analyze_sources([(rel, src)],
                                           allowlist=allowlist)
    return findings, suppressed


def rules_hit(src, rel=SERVE):
    return {f.rule for f in run(src, rel)[0]}


# -- R1: jit-site registry --------------------------------------------------


R1_PASS = """
import jax

class Sched:
    def _step_fns(self, key):
        def decode(p, st, tok):
            return p, st
        fns = {"decode": jax.jit(decode, donate_argnums=(1,))}
        self._fns[key] = fns
        return fns
"""

R1_FAIL = """
import jax

class Sched:
    def handle_request(self, req):
        step = jax.jit(lambda p, st: (p, st))   # per-request jit: bomb
        return step(self.params, self.state)
"""


def test_r1_registered_closure_cache_passes():
    assert "R1" not in rules_hit(R1_PASS)


def test_r1_unregistered_jit_site_fails():
    findings, _ = run(R1_FAIL)
    assert [f.rule for f in findings] == ["R1"]
    assert findings[0].qualname == "Sched.handle_request"


def test_r1_out_of_scope_module_ignored():
    # kernels/ and train/ own their module-level jits; R1 is a serving
    # rule
    assert "R1" not in rules_hit(R1_FAIL, rel="src/repro/train/_fixture.py")
    assert "R1" in rules_hit(R1_FAIL, rel=MODELS)


def test_r1_allowlist_suppresses():
    key = f"R1 {SERVE}::Sched.handle_request"
    findings, suppressed = run(R1_FAIL, allowlist=frozenset({key}))
    assert not findings and len(suppressed) == 1


# -- R2: static-metadata hygiene --------------------------------------------


R2_META_PASS = """
from repro.core.packing import PackedPlane

def build(words, alpha, beta, c):
    return PackedPlane(words=words, alpha=alpha, beta=beta, bits=int(c),
                       pack_axis=-2)
"""

R2_META_FAIL = """
import jax.numpy as jnp
from repro.core.packing import PackedPlane

def build(words, alpha, beta, c):
    # bits as a traced array: the treedef stops hashing, every step
    # retraces
    return PackedPlane(words=words, alpha=alpha, beta=beta,
                       bits=jnp.asarray(c), pack_axis=-2)
"""

R2_DICT_FAIL = """
def consume(plane):
    return plane["words"], plane["alpha"]
"""

R2_DUCK_FAIL = """
def probe(pw):
    return isinstance(pw, dict) and "words" in pw
"""

R2_BRANCH_PASS = """
import jax

def decode(p, x, overflow):
    if x.ndim == 2 and overflow is None:     # static: shape + structure
        return p
    return x

decode_fn = jax.jit(decode)
"""

R2_BRANCH_FAIL = """
import jax

def decode(p, x):
    if x > 0:                                # traced value: runtime error
        return p
    return x

decode_fn = jax.jit(decode)
"""

R2_STATIC_ARGNAMES_PASS = """
import functools
import jax

@functools.partial(jax.jit, static_argnames=("block_n",))
def kernel(x, block_n):
    assert block_n % 8 == 0                  # static by declaration
    return x
"""


@pytest.mark.parametrize("src", [R2_META_PASS, R2_BRANCH_PASS,
                                 R2_STATIC_ARGNAMES_PASS])
def test_r2_clean_snippets_pass(src):
    assert "R2" not in rules_hit(src)


@pytest.mark.parametrize("src,needle", [
    (R2_META_FAIL, "static metadata field `bits`"),
    (R2_DICT_FAIL, "dict-style packed-plane field access"),
    (R2_DUCK_FAIL, "dict-style packed-plane detection"),
    (R2_BRANCH_FAIL, "Python if on data leaf `x`"),
])
def test_r2_violations_fail(src, needle):
    findings, _ = run(src)
    assert any(f.rule == "R2" and needle in f.message for f in findings), \
        [f.format() for f in findings]


def test_r2_jitted_name_is_module_local():
    # an inner closure `prefill` jitted in THIS module must not
    # implicate an unrelated top-level `prefill` in another module
    other = """
def prefill(p, cfg):
    if cfg.use_bias:          # host config branch: fine, not jitted here
        return p
    return None
"""
    findings, _ = analyze_sources(
        [(SERVE, R2_BRANCH_FAIL.replace("decode", "prefill")),
         ("src/repro/models/api2.py", other)])
    assert all(f.path == SERVE for f in findings)


# -- R3: donation discipline ------------------------------------------------


R3_PASS = """
import jax

class Sched:
    def __init__(self, fn):
        self._copy_fn = jax.jit(fn, donate_argnums=(0,))

    def step(self):
        self.state = self._copy_fn(self.state)   # rebind over donation
        return self.state
"""

R3_FAIL = """
import jax

class Sched:
    def __init__(self, fn):
        self._copy_fn = jax.jit(fn, donate_argnums=(0,))

    def step(self):
        out = self._copy_fn(self.state)
        return out, self.state      # read after donate: garbage bytes
"""

R3_DICT_FAIL = """
import jax

def build(decode):
    fns = {"decode": jax.jit(decode, donate_argnums=(1,))}
    return fns

def drive(fns, p, st):
    toks, new_st = fns["decode"](p, st)
    return toks, st.sum()           # stale donated buffer
"""

R3_ALIAS_PASS = """
import jax

def build(decode):
    return {"decode": jax.jit(decode, donate_argnums=(1,))}

def drive(fns, p, st):
    decode_fn = fns["decode"]
    for _ in range(4):
        toks, st = decode_fn(p, st)     # donated arg rebound each call
    return toks, st
"""


def test_r3_rebind_over_donation_passes():
    assert "R3" not in rules_hit(R3_PASS)
    assert "R3" not in rules_hit(R3_ALIAS_PASS)


def test_r3_read_after_donate_fails():
    findings, _ = run(R3_FAIL)
    r3 = [f for f in findings if f.rule == "R3"]
    assert len(r3) == 1 and "self.state" in r3[0].message
    # R3_PASS differs only in rebinding the result over the donated
    # buffer, so the flag is the read, not the donation itself
    assert not [f for f in run(R3_PASS)[0] if f.rule == "R3"]


def test_r3_dict_bound_closure_tracked():
    findings, _ = run(R3_DICT_FAIL)
    assert any(f.rule == "R3" and "`st`" in f.message for f in findings)


# -- R4: host-data contract -------------------------------------------------


R4_PASS = """
import jax

class Sched:
    def _step_fns(self, key):
        cfg = self.cfg              # static trace config: fine to capture
        def decode(p, st, tok, pos, ptab):
            return p, st            # page table flows in as an argument
        return {"decode": jax.jit(decode, donate_argnums=(1,))}
"""

R4_SELF_FAIL = """
import jax

class Sched:
    def _step_fns(self, key):
        def decode(p, st, tok):
            return p[self.pos], st       # scheduler state in the graph
        return {"decode": jax.jit(decode, donate_argnums=(1,))}
"""

R4_CAPTURE_FAIL = """
import jax

class Sched:
    def _step_fns(self, key):
        ptab = self.pool.page_table()
        def decode(p, st, tok):
            return p[ptab], st           # baked-in per-request page table
        return {"decode": jax.jit(decode, donate_argnums=(1,))}
"""


def test_r4_arguments_pass():
    assert "R4" not in rules_hit(R4_PASS)


def test_r4_self_capture_fails():
    findings, _ = run(R4_SELF_FAIL)
    assert any(f.rule == "R4" and "`self`" in f.message for f in findings)


def test_r4_host_data_capture_fails():
    findings, _ = run(R4_CAPTURE_FAIL)
    assert any(f.rule == "R4" and "`ptab`" in f.message for f in findings)


def test_r4_scoped_to_serve():
    assert "R4" not in rules_hit(R4_CAPTURE_FAIL,
                                 rel="src/repro/train/_fixture.py")


# -- the tree itself + CLI contract -----------------------------------------


def _src_sources():
    files = collect_files(["src/repro"])
    return [(p.relative_to(ROOT).as_posix(), p.read_text()) for p in files]


def test_src_tree_is_clean_under_committed_allowlist():
    allowlist = load_allowlist(DEFAULT_ALLOWLIST)
    findings, suppressed = analyze_sources(_src_sources(),
                                           allowlist=allowlist)
    assert not findings, [f.format() for f in findings]
    # the allowlist is exercised, not vestigial: the engine's legacy
    # closures and the scheduler's COW copy closure report through it
    assert {f.allow_key for f in suppressed} == set(allowlist)


def test_every_rule_has_id_title_rationale():
    assert RULE_IDS == ("R1", "R2", "R3", "R4")
    for rule in RULES:
        assert rule.title and len(rule.rationale) > 40


def test_cli_exit_codes(tmp_path):
    env_cmd = [sys.executable, "-m", "tools.analysis"]
    # 0: clean tree (default paths + committed allowlist)
    ok = subprocess.run(env_cmd, cwd=ROOT, capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    # 1: findings (R2 dict-plane access has no path scoping)
    bad = tmp_path / "bad.py"
    bad.write_text("def f(plane):\n    return plane['words']\n")
    hit = subprocess.run(env_cmd + [str(bad)], cwd=ROOT,
                         capture_output=True, text=True)
    assert hit.returncode == 1 and "R2" in hit.stdout
    # 2: analysis errors -- unparseable file, missing path, bad rule id
    broken = tmp_path / "broken.py"
    broken.write_text("def f(:\n")
    for args in ([str(broken)], ["no/such/dir"], ["--rules", "R9"]):
        err = subprocess.run(env_cmd + args, cwd=ROOT,
                             capture_output=True, text=True)
        assert err.returncode == 2, (args, err.stdout, err.stderr)
