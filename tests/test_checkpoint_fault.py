"""Checkpoint/restart, bitwise resume, straggler monitor, heartbeat."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig, SyntheticCorpus
from repro.optim import OptConfig
from repro.runtime import checkpoint as ckpt_mod
from repro.runtime.fault import Heartbeat, StepMonitor, run_resilient
from repro.runtime.sharding import make_mesh
from repro.train import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _tree_equal(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def test_save_restore_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16),
                  "d": jnp.asarray(7, jnp.int32)}}
    ckpt_mod.save(str(tmp_path), 5, tree)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back = ckpt_mod.restore(str(tmp_path), 5, like)
    assert _tree_equal(tree, back)


def test_async_save_and_keep_n(tmp_path):
    mgr = ckpt_mod.CheckpointManager(str(tmp_path), keep=2, async_=True, every=1)
    tree = {"x": jnp.zeros((8,))}
    for step in range(5):
        mgr.maybe_save(step, jax.tree.map(lambda a: a + step, tree))
    mgr.wait()
    steps = sorted(int(d) for d in os.listdir(tmp_path) if d.isdigit())
    assert steps == [3, 4]
    back = mgr.restore(tree)
    np.testing.assert_allclose(np.asarray(back["x"]), 4.0)


def test_restore_structure_mismatch_raises(tmp_path):
    ckpt_mod.save(str(tmp_path), 0, {"a": jnp.zeros(3)})
    with pytest.raises(KeyError):
        ckpt_mod.restore(str(tmp_path), 0, {"b": jnp.zeros(3)})


def test_elastic_restore_with_shardings(tmp_path):
    """Restore device_puts against target shardings (elastic relaunch)."""
    mesh = make_mesh((1,), ("data",))
    sh = jax.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))
    tree = {"w": jnp.arange(16, dtype=jnp.float32)}
    ckpt_mod.save(str(tmp_path), 1, tree)
    back = ckpt_mod.restore(str(tmp_path), 1, tree, shardings={"w": sh})
    assert back["w"].sharding == sh
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))


class TestResilientLoop:
    def _make_pieces(self, tmp_path, crash_at=None):
        cfg = get_config("qwen3_1_7b").reduced().replace(num_layers=1)
        opt = OptConfig(lr=1e-3, total_steps=8)
        corpus = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size, seq_len=16))
        raw_step = jax.jit(make_train_step(cfg, opt))
        crashed = {"done": False}

        def make_state():
            params, opt_state = init_train_state(KEY, cfg, opt)
            return {"params": params, "opt": opt_state}

        def step_fn(state, step):
            if crash_at is not None and step == crash_at and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("injected node failure")
            b = corpus.batch(step, 4, 16)
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            params, opt, _ = raw_step(state["params"], state["opt"], batch)
            return {"params": params, "opt": opt}

        return make_state, step_fn

    def test_crash_resume_bitwise_identical(self, tmp_path):
        make_state, step_fn = self._make_pieces(tmp_path)
        clean_mgr = ckpt_mod.CheckpointManager(str(tmp_path / "clean"),
                                               keep=2, async_=False, every=2)
        clean, r0 = run_resilient(num_steps=8, make_state=make_state,
                                  step_fn=step_fn, ckpt=clean_mgr)
        assert r0 == 0

        make_state2, step_fn2 = self._make_pieces(tmp_path, crash_at=5)
        crash_mgr = ckpt_mod.CheckpointManager(str(tmp_path / "crash"),
                                               keep=2, async_=False, every=2)
        crashed, r1 = run_resilient(num_steps=8, make_state=make_state2,
                                    step_fn=step_fn2, ckpt=crash_mgr)
        assert r1 == 1
        assert _tree_equal(clean["params"], crashed["params"])

    def test_too_many_restarts_raises(self, tmp_path):
        def step_fn(state, step):
            raise RuntimeError("always down")

        mgr = ckpt_mod.CheckpointManager(str(tmp_path), every=0)
        with pytest.raises(RuntimeError):
            run_resilient(num_steps=2, make_state=dict, step_fn=step_fn,
                          ckpt=mgr, max_restarts=2)


def test_straggler_monitor_flags_and_recovers():
    events = []
    mon = StepMonitor(threshold=2.0, warmup_steps=1,
                      on_straggler=events.append)
    for i in range(5):
        mon.record(i, 1.0)
    assert mon.record(5, 5.0) is True      # 5x EMA -> straggler
    assert len(events) == 1 and events[0].step == 5
    assert mon.record(6, 1.0) is False     # EMA not poisoned
    assert abs(mon.ema - 1.0) < 0.05


def test_heartbeat_roundtrip(tmp_path):
    hb = Heartbeat(str(tmp_path / "hb.json"))
    hb.beat(42)
    rec = hb.read()
    assert rec["step"] == 42 and rec["time"] > 0
