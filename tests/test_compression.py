"""Gradient compression: wire-format fidelity + error-feedback decay +
the real shard_map psum (multi-device subprocess)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import compression


def test_compress_decompress_bounded_error():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 0.1}
    out, ef = compression.compress_decompress(g, None, bits=8)
    err = np.abs(np.asarray(out["w"]) - np.asarray(g["w"]))
    scale = 0.1 * 3 / 127  # rough |g|max/qmax
    assert err.max() < scale * 2


def test_error_feedback_mean_converges():
    """EF guarantees: sum of compressed outputs -> sum of true grads."""
    key = jax.random.PRNGKey(1)
    g = jax.random.normal(key, (512,)) * 0.01
    tree = {"g": g}
    ef = None
    acc = jnp.zeros_like(g)
    for _ in range(50):
        out, ef = compression.compress_decompress(tree, ef, bits=4)
        acc = acc + out["g"]
    mean_out = acc / 50
    np.testing.assert_allclose(np.asarray(mean_out), np.asarray(g),
                               atol=float(jnp.abs(g).max()) * 0.05)


def test_ef_residual_bounded():
    g = {"w": jax.random.normal(jax.random.PRNGKey(2), (2048,))}
    ef = None
    for _ in range(20):
        _, ef = compression.compress_decompress(g, ef, bits=8)
    # residual stays at quantization-noise scale; no runaway accumulation
    assert float(jnp.abs(ef["w"]).max()) < float(jnp.abs(g["w"]).max()) * 0.05


_SHARD_MAP_SCRIPT = textwrap.dedent("""
    import os
    # host-platform proxy: force the CPU backend so a TPU-capable
    # container (stripped subprocess env) never probes for accelerators
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.runtime.compression import compressed_psum_tree
    from repro.runtime.sharding import make_mesh, shard_map

    mesh = make_mesh((4,), ("pod",))
    g = jax.random.normal(jax.random.PRNGKey(0), (4, 256)) * 0.1

    def f(g_shard):
        out, ef = compressed_psum_tree({"g": g_shard[0]}, None, "pod", bits=8)
        return out["g"][None], ef["g"][None]

    out, ef = shard_map(f, mesh=mesh, in_specs=P("pod"),
                        out_specs=P("pod"))(g)
    true_mean = jnp.mean(g, axis=0)
    # every pod ends with the same mean-reduced tensor
    for i in range(4):
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(true_mean),
                                   atol=0.1 * 3 / 127 * 4)
    print("SHARD_MAP_OK")
""")


def test_compressed_psum_shard_map_subprocess():
    r = subprocess.run([sys.executable, "-c", _SHARD_MAP_SCRIPT],
                       capture_output=True, text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                       cwd=__file__.rsplit("/tests/", 1)[0])
    assert "SHARD_MAP_OK" in r.stdout, r.stderr[-2000:]
