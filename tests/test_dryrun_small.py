"""CI proxy of the multi-pod dry-run: an 8-device (2x2x2) mesh in a
subprocess (so the main pytest process keeps its single device), with
reduced configs -- proves lower+compile+shardings work end to end for
one cell of each step kind and each family."""

import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    # host-platform proxy: force the CPU backend so a TPU-capable
    # container (stripped subprocess env) never probes for accelerators
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from functools import partial
    from repro.configs import get_config, ShapeConfig, input_specs
    from repro.models import api, common as cm
    from repro.optim import OptConfig, adamw_init
    from repro.runtime import sharding as shard
    from repro.train import make_train_step

    mesh = shard.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cm.set_act_resolver(shard.make_act_resolver(mesh))

    def run(arch, kind):
        cfg = get_config(arch).reduced()
        shape = ShapeConfig("t", 64, 8, kind)
        key = jax.random.PRNGKey(0)
        pspec = jax.eval_shape(partial(api.init, cfg=cfg), key)
        psh = shard.tree_shardings(api.axes(cfg), pspec, mesh)
        bspec = input_specs(cfg, shape)
        bsh = shard.batch_shardings(bspec, mesh)
        if kind == "train":
            step = make_train_step(cfg, OptConfig(), microbatches=2)
            ospec = jax.eval_shape(adamw_init, pspec)
            osh = {"m": psh, "v": psh,
                   "step": jax.NamedSharding(mesh, jax.sharding.PartitionSpec())}
            low = jax.jit(step, in_shardings=(psh, osh, bsh),
                          out_shardings=(psh, osh, None)).lower(pspec, ospec, bspec)
        elif kind == "prefill":
            fn = lambda p, b: api.prefill(p, b, cfg, bits=None, max_len=64)
            sspec = jax.eval_shape(partial(api.init_state, cfg, 8, 64))
            ssh = shard.tree_shardings(api.state_axes(cfg), sspec, mesh)
            low = jax.jit(fn, in_shardings=(psh, bsh),
                          out_shardings=(None, ssh)).lower(pspec, bspec)
        else:
            sspec = jax.eval_shape(partial(api.init_state, cfg, 8, 64))
            ssh = shard.tree_shardings(api.state_axes(cfg), sspec, mesh)
            fn = lambda p, s, t, pos: api.decode_step(p, s, t, pos, cfg, bits=None)
            low = jax.jit(fn, in_shardings=(psh, ssh, bsh["token"], bsh["pos"]),
                          out_shardings=(None, ssh)).lower(
                pspec, sspec, bspec["token"], bspec["pos"])
        c = low.compile()
        ca = c.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca  # 0.4.x compat
        assert ca["flops"] > 0
        print(f"OK {arch} {kind}")

    run("qwen3_1_7b", "train")
    run("granite_moe_1b_a400m", "train")
    run("zamba2_1_2b", "decode")
    run("xlstm_125m", "decode")
    run("whisper_small", "prefill")
    run("qwen2_vl_72b".replace("72b", "72b"), "prefill") if False else None
    print("MINI_DRYRUN_OK")
""")


@pytest.mark.slow
def test_mini_dryrun_all_kinds():
    r = subprocess.run([sys.executable, "-c", _SCRIPT],
                       capture_output=True, text=True, timeout=560,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                       cwd=__file__.rsplit("/tests/", 1)[0])
    assert "MINI_DRYRUN_OK" in r.stdout, (r.stdout[-1000:], r.stderr[-3000:])
