"""Replica fleet serving tests (serve/fleet.py + FleetRouter).

Covers the fleet acceptance surface: per-replica tier assignment under
load (downgrade some-not-all, pin floor for priority traffic),
hysteresis recovery that never skips a rung, zero-request-loss
kill/requeue with token-identical replays, heartbeat/straggler health
signals driving the same drain path, the fleet-managed scheduler mode,
the one-compile-per-representation-key contract per replica, and the
multi-process transport (a SIGKILLed worker is a REAL process death).

Device-count agnostic: on a bare single-device host the in-process
replicas share one device; the `fleet` CI lane reruns this module
under XLA_FLAGS=--xla_force_host_platform_device_count=8 so each
replica owns a disjoint device subset.
"""

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.models import api
from repro.runtime.compile_guard import assert_no_recompiles
from repro.runtime.fault import Heartbeat, StepMonitor
from repro.serve import (Engine, Fleet, FleetRouter, Request, ServeConfig,
                         SubprocessReplica, default_tiers)
from repro.serve.fleet import build_fleet
from repro.serve.metrics import _percentile

KEY = jax.random.PRNGKey(0)
ARCH = "qwen3_1_7b"


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _pinned_thresholds(tiers, replicas):
    """Hold every replica at int8: no load ever crosses a threshold."""
    return (float("inf"),) * (replicas * (len(tiers) - 1))


def _requests(cfg, n, *, prompt_len=8, gen=4, priority=()):
    rng = np.random.default_rng(0)
    return [Request(uid=f"r{i}",
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=prompt_len).astype(np.int32),
                    max_new_tokens=gen, priority=(i in priority))
            for i in range(n)]


@pytest.fixture(scope="module")
def served():
    cfg = get_config(ARCH).reduced()
    params = api.init(KEY, cfg)
    return cfg, params


@pytest.fixture(scope="module")
def single_results(served):
    """Token baseline: the same requests through a 1-replica fleet."""
    cfg, params = served
    tiers = default_tiers(cfg.num_layers)
    fleet = build_fleet(params, cfg, replicas=1, num_slots=2, max_len=32,
                        thresholds=_pinned_thresholds(tiers, 1))
    for req in _requests(cfg, 6):
        fleet.submit(req)
    results = fleet.run_until_idle()
    fleet.close()
    assert fleet.metrics.summary()["requests_lost"] == 0
    return {uid: np.asarray(toks) for uid, toks in results.items()}


# ---------------------------------------------------------------------------
# FleetRouter policy (no model required)
# ---------------------------------------------------------------------------


def test_fleet_router_downgrades_some_not_all():
    tiers = default_tiers(4)
    router = FleetRouter(tiers, 4, pinned=(0,))
    # budget of 5 steps at load 22 (thresholds 4, 8, 12, 16, 20, 24, ...)
    router.observe(22.0, [1.0, 5.0, 3.0, 2.0])
    assert router.indices == [0, 0, 1, 4]
    # some replicas downgraded, some untouched -- never the whole fleet
    assert any(i == 0 for i in router.indices)
    assert any(i > 0 for i in router.indices)


def test_fleet_router_desired_indices_monotone():
    tiers = default_tiers(4)
    router = FleetRouter(tiers, 3, pinned=(0,))
    prev = router.desired_indices(0.0)
    for load in range(0, 200, 3):
        cur = router.desired_indices(float(load))
        assert all(c >= p for c, p in zip(cur, prev)), (load, prev, cur)
        prev = cur


def test_fleet_router_pin_floor_holds_at_any_load():
    tiers = default_tiers(4)
    router = FleetRouter(tiers, 4, pinned=(0,), pin_floor=1)
    router.observe(1e9, [1.0] * 4)
    assert router.indices == [1, 4, 4, 4]
    # the pinned replica's tier keeps >= int4 precision
    assert tiers[router.indices[0]].effective_bits >= 4.0


def test_fleet_router_recovery_never_skips_a_rung():
    tiers = default_tiers(4)
    router = FleetRouter(tiers, 2, pinned=(), cooldown=2)
    router.observe(1e9, [1.0, 1.0])
    assert router.indices == [4, 4]
    seen = [list(router.indices)]
    for _ in range(40):
        router.observe(0.0, [0.0, 0.0])
        if list(router.indices) != seen[-1]:
            seen.append(list(router.indices))
    assert seen[-1] == [0, 0]
    for prev, cur in zip(seen, seen[1:]):
        for p, c in zip(prev, cur):
            assert p - c in (0, 1), (prev, cur)   # one rung at a time
    # int2 -> int8 recovery passed through every rung incl. int2+ep
    r0_path = [s[0] for s in seen]
    assert 3 in r0_path and 2 in r0_path and 1 in r0_path


def test_fleet_router_hysteresis_no_thrash():
    tiers = default_tiers(4)
    router = FleetRouter(tiers, 2, pinned=(), cooldown=4)
    changes = 0
    last = tuple(router.indices)
    for i in range(40):
        load = 5.0 if i % 2 == 0 else 3.0   # oscillate around the 4.0 bar
        router.observe(load, [load / 2] * 2)
        if tuple(router.indices) != last:
            changes += 1
            last = tuple(router.indices)
    # one initial downgrade; the oscillation never completes a cooldown,
    # so the assignment holds instead of flapping
    assert changes == 1
    assert last.count(1) == 1 and last.count(0) == 1


def test_fleet_router_assignment_sticky_when_loads_reorder():
    tiers = default_tiers(4)
    router = FleetRouter(tiers, 3, pinned=())
    router.observe(9.0, [1.0, 2.0, 3.0])      # budget 2 -> r0 absorbs both
    assert router.indices == [2, 0, 0]
    # r0 becomes the hottest replica; the downgrade must NOT bounce to
    # the now-coldest one (sticky fill order: already-downgraded first)
    router.observe(9.0, [50.0, 1.0, 1.0])
    assert router.indices == [2, 0, 0]


def test_fleet_router_validates_thresholds():
    tiers = default_tiers(4)
    with pytest.raises(AssertionError):
        FleetRouter(tiers, 2, thresholds=(1.0, 2.0))      # wrong length
    with pytest.raises(AssertionError):
        FleetRouter(tiers, 1, thresholds=(4.0, 3.0, 2.0, 1.0))  # unsorted


# ---------------------------------------------------------------------------
# fleet logic over stub replicas (dispatch, health, stragglers)
# ---------------------------------------------------------------------------


class StubReplica:
    """Pure-python replica: finishes one request per step."""

    def __init__(self, rid, tiers, *, clock=None, heartbeat=None):
        self.rid = rid
        self.tiers = tuple(tiers)
        self.index = 0
        self.alive = True
        self.killed = False
        self.wedged = False
        self.monitor = None
        self.heartbeat = heartbeat
        self.clock = clock
        self.step_cost = 0.0          # FakeClock seconds per step
        self.dispatched = []
        self._inflight = {}
        self._order = []
        if heartbeat is not None:
            heartbeat.beat(0)

    @property
    def tier_name(self):
        return self.tiers[self.index].name

    def load(self):
        return float(len(self._inflight))

    def submit(self, req, now=None):
        self._inflight[req.uid] = req
        self._order.append(req.uid)
        self.dispatched.append(req.uid)

    def set_tier(self, index):
        self.index = int(index)

    def step(self, now=None):
        if self.killed or not self.alive:
            return {}
        if self.clock is not None:
            self.clock.t += self.step_cost
        if self.wedged:
            return {}
        if self.heartbeat is not None:
            self.heartbeat.beat(0)
        if not self._order:
            return {}
        uid = self._order.pop(0)
        req = self._inflight.pop(uid)
        return {uid: np.arange(req.max_new_tokens, dtype=np.int32)}

    def inflight(self):
        return list(self._inflight.values())

    def drain(self):
        out = list(self._inflight.values())
        self._inflight.clear()
        self._order.clear()
        return out

    def kill(self):
        self.killed = True

    def failure_reason(self, heartbeat_timeout=None, now=None):
        if self.killed:
            return "killed"
        if (heartbeat_timeout is not None and self.heartbeat is not None
                and self.heartbeat.stale(heartbeat_timeout, now=now)):
            return "heartbeat-stale"
        return None

    def close(self):
        self.alive = False


def _stub_fleet(n, *, tiers=None, clock=None, **kw):
    tiers = tiers or default_tiers(4)
    reps = [StubReplica(i, tiers, clock=clock) for i in range(n)]
    fleet = Fleet(reps, tiers, clock=clock or FakeClock(), **kw)
    return fleet, reps


def test_fleet_dispatches_least_loaded(served):
    cfg, _ = served
    tiers = default_tiers(4)
    fleet, reps = _stub_fleet(3, tiers=tiers,
                              thresholds=_pinned_thresholds(tiers, 3))
    # pre-load r0 (inflight only, so the fleet never sees it finish)
    reps[0]._inflight["pre0"] = Request(uid="pre0",
                                        prompt=np.zeros(4, np.int32),
                                        max_new_tokens=1)
    for req in _requests(cfg, 4):
        fleet.submit(req)
    fleet.step()
    # r0 started loaded, so the queue drains onto r1/r2 first and only
    # returns to r0 once the loads equalize
    assert len(reps[1].dispatched) == 2 or len(reps[2].dispatched) == 2
    assert len(reps[0].dispatched) <= 1


def test_fleet_priority_lands_on_pinned_replica_under_overload(served):
    cfg, _ = served
    tiers = default_tiers(4)
    steps = 3 * (len(tiers) - 1)
    fleet, reps = _stub_fleet(3, tiers=tiers,
                              thresholds=(0.5,) * steps, pinned=(0,))
    reqs = _requests(cfg, 12, priority=(2, 7, 11))
    for req in reqs:
        fleet.submit(req)
    fleet.step()
    # the overload drove every unpinned replica to the ladder bottom
    assert fleet.router.indices[1] == fleet.router.indices[2] == 4
    assert fleet.router.indices[0] == 1           # pin floor: int4
    for req in reqs:
        if req.priority:
            assert fleet.metrics.dispatch_replica[req.uid] == 0
            # priority traffic never serves below the int4 pin floor
            assert fleet.metrics.dispatch_tier_index[req.uid] <= 1
    assert any(fleet.metrics.dispatch_replica[r.uid] != 0 for r in reqs
               if not r.priority)
    fleet.run_until_idle()
    assert fleet.metrics.summary()["requests_lost"] == 0


def test_fleet_priority_falls_back_when_pinned_replica_dies(served):
    cfg, _ = served
    tiers = default_tiers(4)
    fleet, reps = _stub_fleet(3, tiers=tiers,
                              thresholds=_pinned_thresholds(tiers, 3),
                              pinned=(0,))
    fleet.kill(0)
    fleet.step()                                   # retires the pinned one
    assert not reps[0].alive
    # r1 busier but serving a better rung than r2
    reps[1].submit(Request(uid="busy", prompt=np.zeros(4, np.int32),
                           max_new_tokens=1))
    fleet.router.indices = [0, 2, 4]
    fleet.submit(Request(uid="pri", prompt=np.zeros(4, np.int32),
                         max_new_tokens=1, priority=True))
    fleet._dispatch(now=0.0)
    # best-bits fallback: priority prefers precision over load
    assert fleet.metrics.dispatch_replica["pri"] == 1


def test_fleet_no_live_replicas_raises(served):
    cfg, _ = served
    tiers = default_tiers(4)
    fleet, _ = _stub_fleet(2, tiers=tiers,
                           thresholds=_pinned_thresholds(tiers, 2))
    fleet.submit(_requests(cfg, 1)[0])
    fleet.kill(0)
    fleet.kill(1)
    with pytest.raises(RuntimeError, match="no live replicas"):
        fleet.step()


def test_fleet_heartbeat_stale_drains_wedged_replica(served, tmp_path):
    cfg, _ = served
    clock = FakeClock()
    tiers = default_tiers(4)
    reps = [StubReplica(i, tiers, clock=clock,
                        heartbeat=Heartbeat(str(tmp_path / f"hb{i}.json"),
                                            clock=clock))
            for i in range(2)]
    fleet = Fleet(reps, tiers, thresholds=_pinned_thresholds(tiers, 2),
                  heartbeat_timeout=5.0, clock=clock)
    for req in _requests(cfg, 8):
        fleet.submit(req)
    fleet.step()
    assert reps[1].inflight()
    reps[1].wedged = True                 # hung but not dead: stops beating
    for _ in range(4):
        clock.t += 3.0
        fleet.step()
    assert not reps[1].alive
    s = fleet.metrics.summary()
    assert s["replica_failures"][0] == {"replica": 1,
                                        "reason": "heartbeat-stale",
                                        "time": pytest.approx(clock.t,
                                                              abs=20.0)}
    assert s["requeued_requests"] >= 1
    fleet.run_until_idle()
    assert fleet.metrics.summary()["requests_lost"] == 0


def test_fleet_straggler_monitor_retires_replica(served):
    cfg, _ = served
    clock = FakeClock()
    tiers = default_tiers(4)
    fleet, reps = _stub_fleet(2, tiers=tiers, clock=clock,
                              thresholds=_pinned_thresholds(tiers, 2),
                              straggler_retire=1)
    flagged = []
    reps[1].monitor = StepMonitor(threshold=2.5, warmup_steps=2,
                                  on_straggler=flagged.append)
    reps[0].step_cost = reps[1].step_cost = 0.01
    for req in _requests(cfg, 8):
        fleet.submit(req)
    for _ in range(4):                    # warm the EMA at healthy speed
        fleet.step()
    reps[1].step_cost = 1.0               # chronic straggler from here on
    for i in range(4):
        fleet.submit(Request(uid=f"late{i}", prompt=np.zeros(4, np.int32),
                             max_new_tokens=1))
    for _ in range(3):
        fleet.step()
    assert not reps[1].alive              # flagged then drained next step
    assert flagged and flagged[0].step_time == pytest.approx(1.0)
    s = fleet.metrics.summary()
    assert s["replica_failures"][0]["reason"] == "straggler"
    assert s["per_replica"]["1"]["straggler_events"] >= 1
    fleet.run_until_idle()
    assert fleet.metrics.summary()["requests_lost"] == 0


# ---------------------------------------------------------------------------
# end-to-end fleets over real engines
# ---------------------------------------------------------------------------


def test_fleet_two_replicas_token_identical_vs_single(served, single_results):
    cfg, params = served
    tiers = default_tiers(cfg.num_layers)
    fleet = build_fleet(params, cfg, replicas=2, num_slots=2, max_len=32,
                        thresholds=_pinned_thresholds(tiers, 2))
    for req in _requests(cfg, 6):
        fleet.submit(req)
    results = fleet.run_until_idle()
    fleet.close()
    s = fleet.metrics.summary()
    assert s["requests_lost"] == 0 and s["requests_completed"] == 6
    # both replicas actually served traffic
    assert all(s["per_replica"][rid]["requests"] > 0 for rid in ("0", "1"))
    assert sorted(results) == sorted(single_results)
    for uid in single_results:
        np.testing.assert_array_equal(results[uid], single_results[uid])


def test_fleet_kill_replica_requeues_with_zero_loss(served, single_results):
    cfg, params = served
    tiers = default_tiers(cfg.num_layers)
    fleet = build_fleet(params, cfg, replicas=2, num_slots=2, max_len=32,
                        thresholds=_pinned_thresholds(tiers, 2))
    for req in _requests(cfg, 6):
        fleet.submit(req)
    fleet.step()
    fleet.step()
    victim_inflight = len(fleet.replicas[1].inflight())
    assert victim_inflight > 0
    fleet.kill(1)
    results = fleet.run_until_idle()
    fleet.close()
    s = fleet.metrics.summary()
    assert s["requests_lost"] == 0 and s["requests_completed"] == 6
    assert s["requeued_requests"] == victim_inflight
    assert s["replica_failures"][0]["reason"] == "killed"
    # requeued requests replay from scratch on the survivor and the
    # greedy decode reproduces the exact same tokens
    for uid in single_results:
        np.testing.assert_array_equal(results[uid], single_results[uid])


@pytest.fixture(scope="module")
def elastic_fleet_run(served):
    """A 2-replica fleet under real load steps (tight thresholds force
    mid-replay downgrades); shared by the occupancy + compile tests."""
    cfg, params = served
    tiers = default_tiers(cfg.num_layers)
    steps = 2 * (len(tiers) - 1)
    fleet = build_fleet(params, cfg, replicas=2, num_slots=2, max_len=32,
                        thresholds=tuple(float(s + 1) for s in range(steps)),
                        pinned=(0,), cooldown=2)
    for req in _requests(cfg, 10):
        fleet.submit(req)
    fleet.run_until_idle()
    yield fleet, tiers
    fleet.close()


def test_fleet_load_step_downgrades_some_replicas(elastic_fleet_run):
    fleet, tiers = elastic_fleet_run
    s = fleet.metrics.summary()
    assert s["requests_lost"] == 0
    low_tiers = {t.name for t in tiers[2:]}       # below int4
    occ0 = s["per_replica"]["0"]["tier_occupancy"]
    occ1 = s["per_replica"]["1"]["tier_occupancy"]
    # the unpinned replica absorbed the downgrade budget...
    assert set(occ1) & low_tiers
    # ...while the pinned one never served below its int4 floor
    assert set(occ0) <= {tiers[0].name, tiers[1].name}
    assert s["tier_switches"] > 0
    assert s["mean_effective_bits_min"] < 8.0


def test_fleet_one_compile_per_representation_per_replica(elastic_fleet_run):
    fleet, tiers = elastic_fleet_run
    for rep in fleet.replicas:
        if rep.engine.packed:
            # packed tiers key per representation: the downgraded
            # replica visited several, each compiled at most once
            counts = assert_no_recompiles(rep.sched)
        else:
            # dequantized tiers share ONE closure (key None): every tier
            # switch must stay a param swap, never a retrace
            counts = assert_no_recompiles(rep.sched, expect_keys={None})
        assert counts["total"] >= 1
    if fleet.replicas[1].engine.packed:
        assert len(fleet.replicas[1].sched._fns) >= 2


# ---------------------------------------------------------------------------
# fleet-managed scheduler mode
# ---------------------------------------------------------------------------


def test_managed_scheduler_external_set_tier(served):
    cfg, params = served
    eng = Engine(params, cfg, ServeConfig(bits=8, max_len=32, num_slots=2))
    tiers = default_tiers(cfg.num_layers)
    sched = eng.scheduler(managed=True, tiers=tiers)
    assert sched.router is None and sched.tier_name == tiers[0].name
    req = _requests(cfg, 1)[0]
    sched.submit(req)
    sched.run_until_idle()
    assert req.uid in sched.results
    sched.set_tier(tiers[1])
    assert sched.tier_name == tiers[1].name
    sched.set_tier(tiers[1])                      # revisit: no-op
    assert sched.tier_name == tiers[1].name


def test_managed_scheduler_rejects_router_knobs(served):
    cfg, params = served
    eng = Engine(params, cfg, ServeConfig(bits=8, max_len=32, num_slots=2))
    with pytest.raises(ValueError, match="mutually exclusive"):
        eng.scheduler(managed=True, elastic=True)
    with pytest.raises(ValueError, match="FleetRouter"):
        eng.scheduler(managed=True, thresholds=(1.0, 2.0, 3.0, 4.0))


def test_set_tier_requires_tier_cache(served):
    cfg, params = served
    eng = Engine(params, cfg, ServeConfig(bits=8, max_len=32, num_slots=2))
    sched = eng.scheduler()                       # fixed tier
    with pytest.raises(ValueError, match="fixed tier"):
        sched.set_tier(default_tiers(cfg.num_layers)[1])


def test_drain_requests_returns_originals_and_frees_slots(served):
    cfg, params = served
    eng = Engine(params, cfg, ServeConfig(bits=8, max_len=32, num_slots=2))
    sched = eng.scheduler(managed=True, tiers=default_tiers(cfg.num_layers))
    reqs = _requests(cfg, 4)
    for req in reqs:
        sched.submit(req)
    sched.step()                                  # admit 2, queue 2
    assert sched.active and sched.queue
    drained = sched.drain_requests()
    assert sorted(r.uid for r in drained) == sorted(r.uid for r in reqs)
    assert all(d is r for d, r in zip(
        sorted(drained, key=lambda r: r.uid),
        sorted(reqs, key=lambda r: r.uid)))       # the ORIGINAL objects
    assert not sched.active and not sched.queue
    assert sched.pool.active_slots == []


# ---------------------------------------------------------------------------
# metrics + fault primitives (satellites)
# ---------------------------------------------------------------------------


def test_percentile_edge_windows():
    assert _percentile([], 50.0) == 0.0           # empty window: a metric
    assert _percentile([], 95.0) == 0.0
    for q in (0.0, 50.0, 95.0, 100.0):
        assert _percentile([2.5], q) == 2.5       # single sample is every q
    xs = [4.0, 1.0, 3.0, 2.0]
    assert _percentile(xs, 0.0) == 1.0
    assert _percentile(xs, 100.0) == 4.0
    assert _percentile(xs, 50.0) == 2.5
    # regression: negative q used to extrapolate BELOW the window min
    assert _percentile(xs, -50.0) == 1.0
    assert _percentile(xs, 400.0) == 4.0


def test_serve_metrics_percentiles_on_empty_and_single_windows():
    from repro.serve.metrics import ServeMetrics
    m = ServeMetrics()
    s = m.summary()
    assert s["p50_ttft_s"] == 0.0 and s["p95_ttft_s"] == 0.0
    m.on_submit("a", 1.0, 8)
    m.on_admit("a", 1.5, "int8")
    m.on_first_token("a", 2.0)
    m.on_finish("a", 3.0, 4)
    s = m.summary()
    assert s["p50_ttft_s"] == pytest.approx(1.0)
    assert s["p95_ttft_s"] == pytest.approx(1.0)  # == p50 for one sample


def test_heartbeat_stale_and_torn_writes(tmp_path):
    clock = FakeClock()
    hb = Heartbeat(str(tmp_path / "hb.json"), clock=clock)
    assert hb.stale(5.0)                          # never beaten
    hb.beat(1)
    assert not hb.stale(5.0)
    clock.t = 10.0
    assert hb.stale(5.0)                          # beat aged out
    hb.beat(2)
    assert not hb.stale(5.0)
    assert hb.read()["step"] == 2
    # torn write (the beater was SIGKILLed mid-write): unreadable IS stale
    with open(hb.path, "w") as f:
        f.write('{"step": 3, "ti')
    assert hb.read() is None
    assert hb.stale(5.0)


def test_step_monitor_zero_ema_baseline_never_flags():
    m = StepMonitor(warmup_steps=2)
    # virtual-clock regime: every step measures 0.0s; a zero EMA carries
    # no straggler information, so nothing may flag (regression: any
    # positive duration after a zero baseline used to flag)
    for i in range(5):
        assert not m.record(i, 0.0)
    assert not m.record(5, 1.0)


def test_step_monitor_flags_and_invokes_callback():
    events = []
    m = StepMonitor(threshold=2.0, warmup_steps=2,
                    on_straggler=events.append)
    for i in range(4):
        assert not m.record(i, 1.0)
    assert m.record(4, 5.0)
    assert len(events) == 1 and events[0].step == 4
    assert events[0].ema == pytest.approx(1.0)
    assert not m.record(5, 1.0)       # the straggler didn't poison the EMA


def test_fleet_metrics_accounts_losses():
    from repro.serve import FleetMetrics
    m = FleetMetrics()
    m.on_submit("a", 0.0, 8)
    m.on_submit("b", 0.0, 8, priority=True)
    m.on_dispatch("a", 0, 0, 0.1)
    m.on_dispatch("b", 1, 1, 0.1)
    m.on_requeue(["a"], 0, 0.5)
    m.on_replica_failure(0, "killed", 0.5)
    m.on_dispatch("a", 1, 1, 0.6)
    m.on_finish("a", 1.0, 4)
    m.on_step({1: "int4"}, {1: 1}, 6.0, 0)        # replica 0 already dead
    s = m.summary()
    assert s["requests_submitted"] == 2
    assert s["requests_lost"] == 1                # "b" never finished
    assert s["requeued_requests"] == 1
    assert s["priority_requests"] == 1
    assert s["replica_failures"][0]["reason"] == "killed"
    assert s["per_replica"]["1"]["requests"] == 2  # a's requeue + b


# ---------------------------------------------------------------------------
# subprocess transport (true multi-process)
# ---------------------------------------------------------------------------


def test_subprocess_replica_roundtrip(served, single_results, tmp_path):
    cfg, _ = served
    rep = SubprocessReplica(0, arch=ARCH, reduced=True, num_slots=2,
                            max_len=32,
                            heartbeat_path=str(tmp_path / "hb.json"))
    try:
        reqs = _requests(cfg, 2)
        for req in reqs:
            rep.submit(req)
        results = {}
        for _ in range(200):
            results.update(rep.step())
            if len(results) == len(reqs):
                break
        assert sorted(results) == sorted(r.uid for r in reqs)
        # the worker rebuilt identical weights from (arch, seed), so its
        # greedy decode matches the in-process baseline token for token
        for req in reqs:
            np.testing.assert_array_equal(results[req.uid],
                                          single_results[req.uid])
        assert rep.failure_reason(heartbeat_timeout=600.0) is None
        tiers = default_tiers(cfg.num_layers)
        rep.set_tier(1)
        assert rep.tier_name == tiers[1].name
    finally:
        rep.close()
    assert rep.proc.poll() == 0                   # clean worker exit


def test_subprocess_fleet_kill_zero_loss(served, single_results):
    cfg, _ = served
    tiers = default_tiers(cfg.num_layers)
    reps = [SubprocessReplica(i, arch=ARCH, reduced=True, num_slots=2,
                              max_len=32)
            for i in range(2)]
    fleet = Fleet(reps, tiers, thresholds=_pinned_thresholds(tiers, 2))
    try:
        for req in _requests(cfg, 6):
            fleet.submit(req)
        fleet.step()
        fleet.step()
        assert reps[1].inflight()
        fleet.kill(1)                             # a REAL SIGKILL
        results = fleet.run_until_idle()
    finally:
        fleet.close()
    s = fleet.metrics.summary()
    assert s["requests_lost"] == 0 and s["requests_completed"] == 6
    assert s["requeued_requests"] >= 1
    assert s["replica_failures"][0]["reason"] == "exited"
    for uid in single_results:
        np.testing.assert_array_equal(results[uid], single_results[uid])
