"""Fused Matryoshka paged-attention kernel tests.

Acceptance surface of the fused decode-attention kernel
(`kernels.paged_attention`, interpret-mode twin on CPU):

  * hypothesis property: the online-softmax recurrence over page tiles
    matches the DENSE masked-softmax oracle (`ref.paged_attend_ref`)
    across random page counts, positions, head groupings and attend
    widths -- fp pages and int8 pages sliced at 8/4/2 bits;
  * bit-exactness: the in-kernel Matryoshka slice + FMA
    (`slice_dequant_tile`) equals `attention.dequant_kv_rows` at fp32
    for every attend width -- equality, not closeness -- so the fused
    path reads exactly the bytes the gather path dequantizes;
  * hole/partial pages: sentinel page-table entries and a partially
    written last page never leak into the output;
  * engine A/B: fused vs gather serving is token-identical at
    kv_bits in {fp, 8, 4, 2} (the `--attn-kernel` flag is a pure
    performance knob);
  * mesh: under the forced multi-device host mesh the fused path stays
    token-identical to the single-device oracle (kv heads shard over
    'model'; tiles are shard-local).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ref
from repro.kernels.paged_attention import (KV_PARENT_BITS,
                                           paged_attend_pallas,
                                           slice_dequant_tile)
from repro.models import api, attention as attn
from repro.serve import Engine, ServeConfig

try:                                    # optional dep (see test_property)
    from hypothesis import given, settings, strategies as st
except ImportError:                     # fixed-seed sweep runs instead
    given = settings = st = None

KEY = jax.random.PRNGKey(0)


def _paged_operands(rng, *, B, kh, G, hd, pages_per_slot, page_size,
                    quantized):
    """Random page store + shuffled page table with sentinel holes.

    Each slot draws a position in [0, pages_per_slot*page_size), takes
    physical pages from a global permutation for its live prefix, and
    carries the hole sentinel (== num_pages) past its high-water page
    -- the exact layout `PagedPool.page_table()` emits.
    """
    P = B * pages_per_slot + 2          # spare pages stay unreferenced
    q = jnp.asarray(rng.standard_normal((B, kh, G, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((P, page_size, kh, hd)) * 2.0,
                    jnp.float32)
    v = jnp.asarray(rng.standard_normal((P, page_size, kh, hd)) * 2.0,
                    jnp.float32)
    pos = rng.integers(0, pages_per_slot * page_size, size=B)
    perm = rng.permutation(P)
    ptab = np.full((B, pages_per_slot), P, np.int32)    # holes everywhere
    taken = 0
    for b in range(B):
        live = int(pos[b]) // page_size + 1
        ptab[b, :live] = perm[taken:taken + live]
        taken += live
    ptab = jnp.asarray(ptab)
    pos = jnp.asarray(pos, jnp.int32)
    if not quantized:
        return q, ptab, pos, (k, v)
    kp, ks, kb = attn.quant_kv_rows(k)
    vp, vs, vb = attn.quant_kv_rows(v)
    return q, ptab, pos, (kp, vp, ks, kb, vs, vb)


# ---------------------------------------------------------------------------
# online softmax vs the dense oracle (property sweep)
# ---------------------------------------------------------------------------


def _check_fp(seed, B, pages_per_slot, page_size):
    """fp pages: flash recurrence over page tiles == dense softmax."""
    rng = np.random.default_rng(seed)
    q, ptab, pos, ops = _paged_operands(
        rng, B=B, kh=2, G=2, hd=8, pages_per_slot=pages_per_slot,
        page_size=page_size, quantized=False)
    got = paged_attend_pallas(q, ptab, pos, *ops, interpret=True)
    want = ref.paged_attend_ref(q, ptab, pos, *ops)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def _check_quant(seed, pages_per_slot, kv_bits):
    """int8 pages at every Matryoshka attend width: the in-tile
    unpack/slice/FMA feeds the same values the gather oracle sees, so
    the only difference is summation order."""
    rng = np.random.default_rng(seed)
    q, ptab, pos, ops = _paged_operands(
        rng, B=2, kh=2, G=2, hd=8, pages_per_slot=pages_per_slot,
        page_size=8, quantized=True)
    got = paged_attend_pallas(q, ptab, pos, *ops, kv_bits=kv_bits,
                              interpret=True)
    want = ref.paged_attend_ref(q, ptab, pos, *ops, kv_bits=kv_bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


if given is not None:
    # hypothesis drives the search when the optional dep is present
    _settings = settings(max_examples=25, deadline=None)

    @_settings
    @given(st.integers(0, 2**31 - 1), st.integers(1, 3), st.integers(1, 4),
           st.sampled_from([4, 8]))
    def test_online_softmax_matches_dense_oracle_fp(seed, B, pages_per_slot,
                                                    page_size):
        _check_fp(seed, B, pages_per_slot, page_size)

    @_settings
    @given(st.integers(0, 2**31 - 1), st.integers(1, 4),
           st.sampled_from([8, 4, 2]))
    def test_online_softmax_matches_dense_oracle_quant(seed, pages_per_slot,
                                                       kv_bits):
        _check_quant(seed, pages_per_slot, kv_bits)
else:
    # deterministic fallback: same oracle comparison over a fixed grid,
    # so the invariant is exercised even without hypothesis installed
    @pytest.mark.parametrize("seed,B,pages_per_slot,page_size",
                             [(0, 1, 1, 4), (1, 2, 2, 8), (2, 3, 3, 4),
                              (3, 2, 4, 8), (4, 1, 4, 4)])
    def test_online_softmax_matches_dense_oracle_fp(seed, B, pages_per_slot,
                                                    page_size):
        _check_fp(seed, B, pages_per_slot, page_size)

    @pytest.mark.parametrize("kv_bits", [8, 4, 2])
    @pytest.mark.parametrize("seed,pages_per_slot", [(0, 1), (1, 2), (2, 4)])
    def test_online_softmax_matches_dense_oracle_quant(seed, pages_per_slot,
                                                       kv_bits):
        _check_quant(seed, pages_per_slot, kv_bits)


# ---------------------------------------------------------------------------
# bit-exactness of the in-kernel slice + hole/partial-page handling
# ---------------------------------------------------------------------------


def test_slice_dequant_tile_bit_exact_vs_dequant_kv_rows():
    """The kernel's per-tile slice+FMA == `dequant_kv_rows` at fp32,
    bit for bit, at every attend width (same parent-grid rescale, same
    r-independent beta offset)."""
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (16, 8),
                          jnp.float32) * 3.0
    codes, alpha, beta = attn.quant_kv_rows(x)
    for r in (KV_PARENT_BITS, 4, 2):
        got = slice_dequant_tile(codes, alpha[:, None], beta[:, None], r)
        want = attn.dequant_kv_rows(codes, alpha, beta, r, jnp.float32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("kv_bits", [8, 4, 2])
def test_holes_and_partial_pages_never_leak(kv_bits):
    """Sentinel page-table holes and a half-written last page must not
    contribute: corrupting every non-live page (including the clamp
    target P-1) leaves the output unchanged."""
    rng = np.random.default_rng(7)
    q, ptab, pos, ops = _paged_operands(
        rng, B=2, kh=1, G=2, hd=8, pages_per_slot=4, page_size=4,
        quantized=True)
    # force partial coverage: slot 0 ends mid-page-1, slot 1 mid-page-0
    pos = jnp.asarray([5, 2], jnp.int32)
    ptab = np.asarray(ptab).copy()
    ptab[0, 2:] = ops[0].shape[0]       # holes past the high-water page
    ptab[1, 1:] = ops[0].shape[0]
    ptab = jnp.asarray(ptab)
    base = paged_attend_pallas(q, ptab, pos, *ops, kv_bits=kv_bits,
                               interpret=True)
    live = {int(p) for b in range(2)
            for p in np.asarray(ptab)[b, :int(pos[b]) // 4 + 1]}
    kp, vp = np.asarray(ops[0]).copy(), np.asarray(ops[1]).copy()
    for p in range(kp.shape[0]):
        if p not in live:
            kp[p] = 255                 # poison dead pages
            vp[p] = 255
    poisoned = (jnp.asarray(kp), jnp.asarray(vp)) + ops[2:]
    got = paged_attend_pallas(q, ptab, pos, *poisoned, kv_bits=kv_bits,
                              interpret=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(got))
    want = ref.paged_attend_ref(q, ptab, pos, *ops, kv_bits=kv_bits)
    np.testing.assert_allclose(np.asarray(base), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# engine A/B: --attn-kernel is a pure performance knob
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("qwen3_1_7b").reduced()
    return cfg, api.init(KEY, cfg)


def _generate(cfg, params, attn_kernel, kv_bits, mesh=None):
    eng = Engine(params, cfg,
                 ServeConfig(bits=4, max_len=32, num_slots=2, page_size=8,
                             kv_bits=kv_bits, attn_kernel=attn_kernel),
                 mesh=mesh)
    prompts = jax.random.randint(jax.random.fold_in(KEY, 13), (3, 14), 0,
                                 cfg.vocab_size)
    return np.asarray(eng.generate(prompts, 6))


@pytest.mark.parametrize("kv_bits", ["fp", 8, 4, 2])
def test_fused_vs_gather_token_identical(dense, kv_bits):
    cfg, params = dense
    fused = _generate(cfg, params, "fused", kv_bits)
    gather = _generate(cfg, params, "gather", kv_bits)
    np.testing.assert_array_equal(fused, gather)


def test_attn_kernel_validated():
    with pytest.raises(ValueError):
        ServeConfig(kv_bits=8, attn_kernel="dense").kv_config()


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs a forced multi-device host mesh (run via "
                           "the shard CI job)")
def test_fused_token_identical_on_mesh(dense):
    """Model-parallel 2: kv heads shard over 'model'; the fused kernel
    stays token-identical to the single-device oracle."""
    from repro.launch.mesh import make_host_mesh
    cfg, params = dense
    single = _generate(cfg, params, "fused", 8)
    meshed = _generate(cfg, params, "fused", 8, mesh=make_host_mesh(2))
    np.testing.assert_array_equal(single, meshed)
