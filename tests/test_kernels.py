"""Pallas kernels vs pure-jnp oracles (interpret mode shape/dtype sweep)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing, quant
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("bits", [8, 4, 2])
@pytest.mark.parametrize("shape", [(16, 128, 128), (8, 256, 384), (33, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_matches_ref(bits, shape, dtype):
    M, K, N = shape
    kx, kw = jax.random.split(jax.random.fold_in(KEY, hash((bits,) + shape) % 2**31))
    x = jax.random.normal(kx, (M, K), jnp.float32).astype(dtype)
    w = jax.random.normal(kw, (K, N), jnp.float32)
    pl = packing.PackedLinear.from_weights(w)
    words, alpha, beta = pl.materialize(bits)
    y_k = ops.quant_matmul(x, words, alpha, beta, bits=bits)
    y_r = ref.quant_matmul_ref(x, words, alpha, beta, bits=bits)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(y_k, np.float32), np.asarray(y_r, np.float32),
        rtol=tol, atol=tol * K ** 0.5)


@pytest.mark.parametrize("bits", [8, 4, 2])
def test_quant_matmul_matches_fake_quant_truth(bits):
    """Kernel output == x @ quant_dequant(w) -- the deployment contract."""
    kx, kw = jax.random.split(KEY)
    x = jax.random.normal(kx, (16, 256), jnp.float32)
    w = jax.random.normal(kw, (256, 128), jnp.float32)
    pl = packing.PackedLinear.from_weights(w)
    words, alpha, beta = pl.materialize(bits)
    y_k = ops.quant_matmul(x, words, alpha, beta, bits=bits)
    y_t = x @ quant.quant_dequant(w, 8, bits, axis=0)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_t),
                               rtol=1e-4, atol=1e-3)


def test_quant_matmul_extra_precision_composition():
    kx, kw = jax.random.split(jax.random.fold_in(KEY, 7))
    x = jax.random.normal(kx, (8, 128), jnp.float32)
    w = jax.random.normal(kw, (128, 128), jnp.float32)
    pl = packing.PackedLinear.from_weights(w)
    words, alpha, beta, over = pl.materialize(2, extra_precision=True)
    y_k = ops.quant_matmul(x, words, alpha, beta, bits=2, overflow_words=over)
    y_t = x @ quant.quant_dequant(w, 8, 2, axis=0, extra_precision=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_t),
                               rtol=1e-4, atol=1e-3)
    y_ref = ref.quant_matmul_ep_ref(x, words, alpha, beta, over, bits=2)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


def test_quant_matmul_batched_leading_dims():
    kx, kw = jax.random.split(KEY)
    x = jax.random.normal(kx, (2, 5, 128), jnp.float32)
    w = jax.random.normal(kw, (128, 64), jnp.float32)
    pl = packing.PackedLinear.from_weights(w)
    words, alpha, beta = pl.materialize(4)
    y = ops.quant_matmul(x, words, alpha, beta, bits=4)
    assert y.shape == (2, 5, 64)
    y_flat = ops.quant_matmul(x.reshape(10, 128), words, alpha, beta, bits=4)
    np.testing.assert_allclose(np.asarray(y.reshape(10, 64)),
                               np.asarray(y_flat), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(128, 128), (300, 200), (1024, 64)])
@pytest.mark.parametrize("bitwidths", [(8, 4, 2), (8,), (6, 3)])
def test_fused_quantize_matches_ref(shape, bitwidths):
    w = jax.random.normal(jax.random.fold_in(KEY, hash(shape + bitwidths) % 2**31),
                          shape, jnp.float32)
    outs = ops.fused_quantize(w, bitwidths=bitwidths)
    refs = ref.fused_quantize_ref(w, bitwidths=bitwidths)
    for o, r, b in zip(outs, refs, bitwidths):
        diff = np.abs(np.asarray(o) - np.asarray(r))
        # one quantization step of slack for fp rounding knife-edges,
        # allowed on at most 1e-4 of elements; everything else exact.
        step = (np.asarray(w).max(0) - np.asarray(w).min(0)) / (2**b - 1)
        knife = diff > 1e-5
        assert knife.mean() <= 1e-4, (b, knife.mean())
        assert (diff <= step[None, :] * (1 + 1e-5) + 1e-6).all(), (b, diff.max())


def test_fused_quantize_extra_precision():
    w = jax.random.normal(KEY, (256, 128), jnp.float32)
    outs = ops.fused_quantize(w, bitwidths=(2,), extra_precision=True)
    refs = ref.fused_quantize_ref(w, bitwidths=(2,), extra_precision=True)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(refs[0]),
                               rtol=1e-5, atol=1e-5)


def test_serve_linear_end_to_end():
    kx, kw = jax.random.split(KEY)
    x = jax.random.normal(kx, (4, 256), jnp.float32)
    w = jax.random.normal(kw, (256, 128), jnp.float32)
    pl = packing.PackedLinear.from_weights(w)
    for bits in (8, 4, 2):
        y = ops.serve_linear(x, pl, bits)
        y_t = x @ quant.quant_dequant(w, 8, bits, axis=0)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_t),
                                   rtol=1e-4, atol=1e-3)
