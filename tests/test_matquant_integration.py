"""End-to-end behaviour tests of the paper's central claims, at tiny
scale on the synthetic corpus:

  1. sliced int8->int2 of a plain QAT model collapses (Table 1/2
     'Sliced int8' rows), while a MatQuant model's int2 slice works;
  2. MatQuant int2 is no worse than an int2-only baseline at equal
     steps (paper: substantially better);
  3. interpolated int6/int3 (never trained) stay close to int8 quality;
  4. co-distillation config runs and trains;
  5. Single-Precision MatQuant trains the int2 slice of an int8 parent.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.matquant import cross_entropy
from repro.core.quant import QuantConfig
from repro.data import DataConfig, SyntheticCorpus
from repro.models import api
from repro.optim import OptConfig
from repro.train import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)
STEPS = 60
BATCH, SEQ = 8, 64


def _cfg(qcfg):
    return (get_config("qwen3_1_7b").reduced()
            .replace(num_layers=2, quant=qcfg))


def _train(cfg, steps=STEPS, seed=0):
    opt = OptConfig(lr=3e-3, total_steps=steps, warmup_steps=5)
    params, opt_state = init_train_state(jax.random.PRNGKey(seed), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))
    corpus = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=SEQ, seed=11))
    for i in range(steps):
        b = corpus.batch(i, BATCH, SEQ)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, m = step(params, opt_state, batch)
    return params, m


def _eval_nll(params, cfg, bits):
    # same corpus seed (same Markov structure); held-out step range
    corpus = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=SEQ, seed=11))
    b = corpus.batch(10_000, 16, SEQ)
    logits, _ = api.forward(params, {"tokens": jnp.asarray(b["tokens"])},
                            cfg, bits=bits)
    return float(cross_entropy(logits, jnp.asarray(b["labels"])))


@pytest.fixture(scope="module")
def trained():
    """Train three variants once for the whole module."""
    mat_cfg = _cfg(QuantConfig(mode="qat", bitwidths=(8, 4, 2),
                               weights=(0.1, 0.1, 1.0)))
    base8_cfg = _cfg(QuantConfig(mode="qat", bitwidths=(8,), weights=(1.0,)))
    base2_cfg = _cfg(QuantConfig(mode="qat", bitwidths=(2,), weights=(1.0,),
                                 parent_bits=2))
    mat, _ = _train(mat_cfg)
    base8, _ = _train(base8_cfg)
    base2, _ = _train(base2_cfg)
    return dict(mat=(mat, mat_cfg), base8=(base8, base8_cfg),
                base2=(base2, base2_cfg))


def test_sliced_int8_collapses_matquant_does_not(trained):
    mat, mat_cfg = trained["mat"]
    base8, base8_cfg = trained["base8"]
    # slicing the int8-only baseline to int2 (paper's 'Sliced int8' row)
    sliced_nll = _eval_nll(base8, base8_cfg, bits=2)
    mat_nll = _eval_nll(mat, mat_cfg, bits=2)
    assert mat_nll < sliced_nll, (mat_nll, sliced_nll)


def test_matquant_int2_not_worse_than_baseline_int2(trained):
    mat, mat_cfg = trained["mat"]
    base2, base2_cfg = trained["base2"]
    mat_nll = _eval_nll(mat, mat_cfg, bits=2)
    base_nll = _eval_nll(base2, base2_cfg, bits=2)
    assert mat_nll <= base_nll * 1.10, (mat_nll, base_nll)


def test_interpolated_bits_between_neighbours(trained):
    mat, mat_cfg = trained["mat"]
    nll = {b: _eval_nll(mat, mat_cfg, bits=b) for b in (8, 6, 4, 3, 2)}
    # int6 close to int8; int3 between int4 and int2 (small slack)
    assert nll[6] <= nll[8] * 1.05 + 0.05
    assert nll[3] <= nll[2] * 1.05 + 0.05
    assert nll[2] >= nll[8] - 0.05  # monotone-ish overall


def test_matquant_int8_close_to_baseline_int8(trained):
    mat, mat_cfg = trained["mat"]
    base8, base8_cfg = trained["base8"]
    assert _eval_nll(mat, mat_cfg, 8) <= _eval_nll(base8, base8_cfg, 8) * 1.15


def test_codistillation_trains():
    cfg = _cfg(QuantConfig(mode="qat", bitwidths=(8, 4, 2),
                           weights=(0.1, 0.1, 1.0), codistill=((8, 2),)))
    params, metrics = _train(cfg, steps=10)
    assert "distill_8to2" in metrics
    assert bool(jnp.isfinite(metrics["distill_8to2"]))


def test_single_precision_matquant_trains_sliced_int2():
    """R={2} with parent int8 (Section 5.3): loss only over the slice."""
    cfg = _cfg(QuantConfig(mode="qat", bitwidths=(2,), weights=(1.0,),
                           parent_bits=8))
    params, metrics = _train(cfg, steps=30)
    nll2 = _eval_nll(params, cfg, 2)
    # the int8 parent of an S.P. model is still evaluable (Table 23/24)
    nll8 = _eval_nll(params, cfg, 8)
    assert jnp.isfinite(nll2) and jnp.isfinite(nll8)


def test_extra_precision_improves_int2():
    cfg_ep = _cfg(QuantConfig(mode="qat", bitwidths=(8, 4, 2),
                              weights=(1.0, 1.0, 1.0), extra_precision=True))
    params, _ = _train(cfg_ep, steps=STEPS)
    nll_ep = _eval_nll(params, cfg_ep, 2)
    assert jnp.isfinite(nll_ep)
