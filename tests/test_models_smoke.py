"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and finiteness; plus
prefill/decode consistency against the teacher-forced forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core.quant import QuantConfig
from repro.models import api
from repro.optim import OptConfig
from repro.train import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg, key=KEY):
    kt, kf, kv = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(kt, (B, S), 0, cfg.vocab_size, jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(kf, (B, cfg.encoder_len, cfg.d_model),
                                            jnp.float32)
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(kv, (B, 4, cfg.d_model))
        pos = jnp.arange(S, dtype=jnp.int32)
        batch["positions"] = jnp.broadcast_to(pos[None, :, None], (B, S, 3))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = api.init(KEY, cfg)
    batch = _batch(cfg)
    for bits in (None, 8, 2):
        logits, aux = api.forward(params, batch, cfg, bits=bits)
        assert logits.shape == (B, S, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits).all()), (arch, bits)


@pytest.mark.parametrize("arch", ARCH_IDS[:10])  # the 10 assigned archs
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    opt = OptConfig(lr=1e-3, total_steps=10)
    params, opt_state = init_train_state(KEY, cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))
    batch = _batch(cfg)
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(opt_state2["step"]) == 1
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "granite_moe_1b_a400m",
                                  "xlstm_125m", "whisper_small", "zamba2_1_2b"])
def test_prefill_decode_matches_forward(arch):
    """decode(prefill(t[:k]), t[k:]) logits == forward(t) logits."""
    cfg = get_config(arch).reduced()
    params = api.init(KEY, cfg)
    batch = _batch(cfg)
    toks = batch["tokens"]
    full_logits, _ = api.forward(params, {k: v for k, v in batch.items()
                                          if k != "labels"}, cfg, bits=8)
    k = S // 2
    pre_batch = {kk: (v[:, :k] if kk == "tokens" else v)
                 for kk, v in batch.items() if kk != "labels"}
    if cfg.family == "vlm":
        pre_batch["positions"] = batch["positions"][:, :k]
    logits_k, state = api.prefill(params, pre_batch, cfg, bits=8, max_len=S)
    np.testing.assert_allclose(
        np.asarray(logits_k[:, -1], np.float32),
        np.asarray(full_logits[:, k - 1], np.float32), rtol=2e-2, atol=2e-2)
    # decode the next tokens one by one and compare
    for i in range(k, min(k + 3, S)):
        tok = toks[:, i:i + 1]
        logits_i, state = api.decode_step(params, state, tok,
                                          jnp.asarray(i, jnp.int32), cfg, bits=8)
        np.testing.assert_allclose(
            np.asarray(logits_i[:, 0], np.float32),
            np.asarray(full_logits[:, i], np.float32), rtol=2e-2, atol=2e-2)


def test_mixnmatch_per_layer_bits_changes_output():
    cfg = get_config("qwen3_1_7b").reduced()
    params = api.init(KEY, cfg)
    batch = _batch(cfg)
    del batch["labels"]
    l_uniform, _ = api.forward(params, batch, cfg, bits=2)
    l_mix, _ = api.forward(params, batch, cfg, bits=[8, 2])
    l_mix2, _ = api.forward(params, batch, cfg, bits=[2, 2])
    assert not np.allclose(np.asarray(l_uniform), np.asarray(l_mix))
    np.testing.assert_allclose(np.asarray(l_uniform), np.asarray(l_mix2),
                               rtol=1e-4, atol=1e-4)


def test_param_count_analytic_close_to_actual():
    for arch in ("qwen3_1_7b", "granite_moe_1b_a400m", "zamba2_1_2b",
                 "whisper_small", "xlstm_125m"):
        cfg = get_config(arch).reduced()
        params = api.init(KEY, cfg)
        actual = api.param_count(params)
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.12, (arch, actual, analytic)
