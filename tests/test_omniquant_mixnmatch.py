"""OmniQuant calibration quality + Mix'n'Match strategy behaviour."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import mixnmatch
from repro.core.matquant import recon_loss_multi
from repro.core.quant import QuantConfig
from repro.models import api
from repro.models.lm import _dense_block
from repro.train import omniquant_calib

KEY = jax.random.PRNGKey(0)


def test_calibration_reduces_reconstruction_error():
    cfg = (get_config("mistral_7b").reduced()
           .replace(num_layers=1, quant=QuantConfig(mode="omniquant")))
    params = api.init(KEY, cfg)
    toks = jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size, jnp.int32)
    x = jnp.take(params["embed"]["w"], toks, axis=0)
    positions = jnp.broadcast_to(jnp.arange(32, dtype=jnp.int32), (4, 32))
    lp = jax.tree.map(lambda a: a[0], params["layers"])

    def recon(lp_):
        block_fp = lambda xin: _dense_block(lp_, xin, cfg, None, positions,
                                            cfg.quant, cfg.attn_chunk)
        block_q = lambda p, xi, bits: _dense_block(p, xi, cfg, bits, positions,
                                                   cfg.quant, cfg.attn_chunk)
        loss, _ = recon_loss_multi(block_fp, block_q, lp_, x, cfg.quant)
        return float(loss)

    before = recon(lp)
    calibrated, losses = omniquant_calib.calibrate(
        params, cfg, toks, steps_per_layer=40, lr=5e-3)
    lp_after = jax.tree.map(lambda a: a[0], calibrated["layers"])
    after = recon(lp_after)
    assert after < before, (before, after)


def test_omniquant_freezes_weights():
    cfg = (get_config("mistral_7b").reduced()
           .replace(num_layers=1, quant=QuantConfig(mode="omniquant")))
    params = api.init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size, jnp.int32)
    calibrated, _ = omniquant_calib.calibrate(params, cfg, toks,
                                              steps_per_layer=5, lr=1e-2)
    w_before = params["layers"]["ffn"]["up"]["w"]
    w_after = calibrated["layers"]["ffn"]["up"]["w"]
    np.testing.assert_array_equal(np.asarray(w_before), np.asarray(w_after))
    g_before = params["layers"]["ffn"]["up"]["omni"]["gamma_logit"]
    g_after = calibrated["layers"]["ffn"]["up"]["omni"]["gamma_logit"]
    assert not np.array_equal(np.asarray(g_before), np.asarray(g_after))


def test_mixnmatch_strategies_shapes():
    for strat in mixnmatch.STRATEGIES:
        a = mixnmatch.assign(12, 4.5, strat)
        assert len(a) == 12
    inc = mixnmatch.assign(12, 4.5, "increasing")
    assert inc == sorted(inc)
    dec = mixnmatch.assign(12, 4.5, "decreasing")
    assert dec == sorted(dec, reverse=True)


def test_mixnmatch_sweep_monotone_budget():
    pts = mixnmatch.sweep(16, points=7)
    effs = [e for e, _ in pts]
    assert effs == sorted(effs)
    assert effs[0] <= 2.5 and effs[-1] >= 7.5


def test_exhaustive_pareto_tiny():
    # quality proxy: lower is better, favouring more bits on layer 1
    def eval_fn(a):
        return -(a[0] * 1.0 + a[1] * 3.0)

    pareto = mixnmatch.exhaustive_pareto(2, eval_fn)
    assert pareto[-1][2] == (8, 8)
    # pareto quality strictly improves along the frontier
    quals = [q for _, q, _ in pareto]
    assert quals == sorted(quals, reverse=True)
