"""Packed elastic-tier serving: per-bitwidth compiled closures, batched
bucketed admission with donated state, and packed/dequant equivalence
(including through the interpret-mode Pallas kernel)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.packing import PackedPlane
from repro.models import api
from repro.runtime.compile_guard import assert_no_recompiles
from repro.serve import (Engine, Request, ServeConfig, TierCache,
                         default_tiers, materialize_packed_params,
                         materialize_served_params)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def served():
    cfg = get_config("qwen3_1_7b").reduced()
    params = api.init(KEY, cfg)
    eng = Engine(params, cfg, ServeConfig(bits=8, max_len=32, num_slots=4,
                                          page_size=8))
    return params, cfg, eng


def _tier(cfg, name):
    return next(t for t in default_tiers(cfg.num_layers) if t.name == name)


# ---------------------------------------------------------------------------
# packed-tier equivalence on the interpret-mode kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4, 2])
def test_packed_decode_step_matches_dequant_on_interpret_kernel(served, bits):
    """Sliced packed decode step == dequantized decode step, with the
    packed planes consumed by the Pallas kernel in interpret mode."""
    params, cfg, _ = served
    cfg_k = cfg.replace(quant=dataclasses.replace(
        cfg.quant, packed_bits=bits, packed_kernel=True))
    pp = materialize_packed_params(params, cfg_k, bits)
    sp = materialize_served_params(params, cfg, bits)
    state = api.init_state(cfg, 2, 16)
    tok = jax.random.randint(jax.random.fold_in(KEY, bits), (2, 1), 0,
                             cfg.vocab_size)
    pos = jnp.asarray([3, 7], jnp.int32)
    lk, _ = api.decode_step_slots(pp, state, tok, pos, cfg_k, bits=None)
    ld, _ = api.decode_step_slots(sp, state, tok, pos, cfg, bits=None)
    np.testing.assert_allclose(np.asarray(lk), np.asarray(ld),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(lk, -1)),
                                  np.asarray(jnp.argmax(ld, -1)))


def test_tier_cache_packed_bytes_halve_and_mnm_packs_per_layer(served):
    params, cfg, _ = served
    cache = TierCache(params, cfg, packed=True)
    e8 = cache.get(_tier(cfg, "int8"))
    e4 = cache.get(_tier(cfg, "int4"))
    e2 = cache.get(_tier(cfg, "int2"))
    # the sliced plane bytes halve exactly per tier step down
    assert e8.packed_nbytes == 2 * e4.packed_nbytes == 4 * e2.packed_nbytes > 0
    assert (e8.packed_bits, e4.packed_bits, e2.packed_bits) == (8, 4, 2)
    # packed planes really replaced the scoped projections
    up = e4.params["layers"]["ffn"]["up"]["w"]
    assert isinstance(up, PackedPlane) and up.bits == 4
    # Mix'n'Match (per-layer bits) serves PER-LAYER packed planes behind
    # the same get() interface: layers unstacked, layer l at bits[l],
    # plane bytes between the uniform tiers per the per-layer bit sum
    mnm = next(t for t in default_tiers(cfg.num_layers)
               if not isinstance(t.bits, int))
    em = cache.get(mnm)
    assert em.packed_bits == tuple(mnm.bits)
    for l, b in enumerate(mnm.bits):
        plane = em.params["layers"][l]["ffn"]["up"]["w"]
        assert isinstance(plane, PackedPlane) and plane.bits == b
    assert e8.packed_nbytes > em.packed_nbytes > e2.packed_nbytes
    # cached: a second get is the same entry
    assert cache.get(_tier(cfg, "int4")) is e4


# ---------------------------------------------------------------------------
# mid-flight tier switching: per-bitwidth closures, no recompile on revisit
# ---------------------------------------------------------------------------


def _drive(sched, cfg, indices):
    """Submit two requests, then step through `indices` tier switches."""
    rng = np.random.default_rng(11)
    for i in range(2):
        sched.submit(Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 8),
                             max_new_tokens=len(indices) + 1))
    for idx in indices:
        sched.router.index = idx
        sched.step()
    sched.router.index = 0
    return sched.run_until_idle()


def test_tier_switch_no_recompile_within_bitwidth_and_exact_results(served):
    params, cfg, eng = served
    # cooldown is huge so the router holds whatever index the test sets
    # (index 4 = int2 on the 5-rung ladder; int2+ep is covered in
    # tests/test_packed_ep.py)
    switches = [0, 1, 4, 1, 0, 4]           # int8 -> int4 -> int2 -> ...
    sp = eng.scheduler(elastic=True, packed=True, cooldown=10_000)
    sd = eng.scheduler(elastic=True, packed=False, cooldown=10_000)
    rp = _drive(sp, cfg, switches)
    rd = _drive(sd, cfg, switches)
    # packed planes and dequantized weights decode the same tokens
    # across every switch (identical dequant math)
    for uid in rd:
        np.testing.assert_array_equal(rp[uid], rd[uid])
    # one compiled closure pair per packed bitwidth, warmed lazily, and
    # revisiting a bitwidth reused it: exactly one decode compile per
    # bitwidth even though each tier was served multiple times
    assert_no_recompiles(sp, expect_keys={8, 4, 2})
    assert_no_recompiles(sd, expect_keys={None})


def test_scheduler_accepts_packed_fixed_tier(served, monkeypatch):
    """A packed-checkpoint engine no longer needs a dequantized detour:
    the fixed-tier scheduler keys its closures by the engine bitwidth."""
    params, cfg, _ = served
    import repro.serve.engine as engine_mod
    monkeypatch.setattr(engine_mod, "_packed_backend_ok", lambda: True)
    eng = Engine(params, cfg, ServeConfig(bits=4, max_len=32, num_slots=2,
                                          page_size=8, use_packed=True))
    assert eng.packed
    sched = eng.scheduler(num_slots=2, max_len=32)
    assert sched.packed_bits == 4
    prompts = jax.random.randint(jax.random.fold_in(KEY, 3), (2, 8), 0,
                                 cfg.vocab_size)
    out = np.asarray(eng.generate(prompts, 4))   # facade -> scheduler path
    batch_sched = next(iter(eng._schedulers.values()))
    assert_no_recompiles(batch_sched, expect_keys={4})   # packed-bitwidth closure
    ref = Engine(params, cfg, ServeConfig(bits=4, max_len=32, num_slots=2,
                                          page_size=8))
    np.testing.assert_array_equal(out, np.asarray(ref.generate(prompts, 4)))


# ---------------------------------------------------------------------------
# batched bucketed admission + donated state
# ---------------------------------------------------------------------------


def test_burst_admission_issues_one_prefill_per_bucket(served):
    params, cfg, eng = served
    sched = eng.scheduler(num_slots=4, max_len=32)
    rng = np.random.default_rng(4)
    # 3 prompts in the 8-token bucket + 1 in the 16-token bucket
    for i, plen in enumerate((8, 6, 7, 12)):
        sched.submit(Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, plen),
                             max_new_tokens=3))
    assert sched.prefill_calls == 0
    sched.step()
    assert len(sched.active) == 4           # the whole burst was admitted...
    assert sched.prefill_calls == 2         # ...with <= #buckets prefills
    res = sched.run_until_idle()
    assert sorted(res) == [0, 1, 2, 3]
    assert all(len(res[i]) == 3 for i in range(4))


def test_burst_admission_tokens_match_sequential_runs(served):
    """Bucketed batched admission is exact: each request decodes the
    same tokens as an isolated legacy run (mixed prompt lengths)."""
    params, cfg, eng = served
    sched = eng.scheduler(num_slots=4, max_len=32)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, plen)
               for plen in (8, 5, 12, 8)]
    for i, p in enumerate(prompts):
        sched.submit(Request(uid=i, prompt=p, max_new_tokens=5))
    res = sched.run_until_idle()
    for i, p in enumerate(prompts):
        iso = np.asarray(eng.generate_legacy(jnp.asarray(p[None]), 5))[0]
        np.testing.assert_array_equal(res[i], iso)


def test_admission_and_decode_donate_state(served):
    """The jitted step closures donate the slot-array state: the previous
    state buffers are consumed in place, not copied per call."""
    params, cfg, eng = served
    sched = eng.scheduler(num_slots=2, max_len=32)
    rng = np.random.default_rng(6)
    sched.submit(Request(uid="a", prompt=rng.integers(0, cfg.vocab_size, 8),
                         max_new_tokens=4))
    before = jax.tree.leaves(sched.state)[0]
    sched.step()                            # admission prefill consumes it
    assert before.is_deleted()
    mid = jax.tree.leaves(sched.state)[0]
    sched.step()                            # decode step consumes it too
    assert mid.is_deleted()
