"""Packed extra-precision (overflow-bitmap) serving: the interpret-mode
kernel composes the 2^r-valued overflow term in-tile and matches the
dequantized Errata-Eq.-8 oracle on every plane layout (dense K-packed,
MoE expert stacks, N-packed down projections); TierCache reports the
dense bitmap in packed bytes and the Table-7 effective bits; the
elastic scheduler downgrades into the int2+ep rung mid-flight with one
compile per representation key."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import packing, quant
from repro.core.packing import PackedLinear, PackedPlane, packed_rep_key
from repro.kernels import ops
from repro.models import api
from repro.serve import (Engine, Request, ServeConfig, TierCache,
                         default_tiers, materialize_packed_params,
                         materialize_served_params)
from repro.serve.engine import build_packed_parent
from repro.runtime.compile_guard import assert_no_recompiles

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def served():
    cfg = get_config("qwen3_1_7b").reduced()
    params = api.init(KEY, cfg)
    eng = Engine(params, cfg, ServeConfig(bits=8, max_len=32, num_slots=4,
                                          page_size=8))
    return params, cfg, eng


# ---------------------------------------------------------------------------
# interpret-kernel oracle: plane_matmul(ep) == dequantized ep matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [2, 4])
def test_plane_matmul_ep_dense_matches_dequant_oracle(bits):
    """One kernel call composes base plane + 2^r-valued overflow term;
    K is a multiple of 32, so this runs the Pallas kernel (interpret)."""
    k, n = 128, 64
    w = jax.random.normal(jax.random.fold_in(KEY, bits), (k, n), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, bits + 1), (3, k),
                          jnp.float32)
    plane = PackedLinear.from_weights(w).materialize_plane(
        bits, extra_precision=True)
    assert plane.extra_precision and plane.overflow is not None
    assert plane.overflow.shape == (k // 32, n)
    y = ops.plane_matmul(x, plane, use_kernel=True)
    ref = x @ quant.quant_dequant(w, 8, bits, axis=0, extra_precision=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # the jnp twin is the same math
    y_twin = ops.plane_matmul(x, plane, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y_twin), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bits", [2, 4])
def test_plane_matmul_ep_expert_stack_matches_oracle(bits):
    """Extra-precision MoE expert stack through the expert-batched
    kernel: the (E, K/32, N) bitmap rides the same grid over E."""
    E, M, k, n = 3, 5, 64, 32
    w = jax.random.normal(jax.random.fold_in(KEY, 10 + bits), (E, k, n),
                          jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 11 + bits), (E, M, k),
                          jnp.float32)
    plane = PackedLinear.from_weights(w).materialize_plane(
        bits, extra_precision=True)
    assert plane.overflow.shape == (E, k // 32, n)
    y = ops.plane_matmul(x, plane, use_kernel=True)
    ref = jax.vmap(
        lambda xe, we: xe @ quant.quant_dequant(we, 8, bits, axis=0,
                                                extra_precision=True))(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_plane_matmul_ep_n_packed_matches_oracle():
    """N-packed (down/wo-type) ep plane: the jnp twin adds the overflow
    term to codes unpacked along the OUTPUT dim."""
    k, n = 48, 40                      # ragged vs cpw on both dims
    w = jax.random.normal(jax.random.fold_in(KEY, 20), (k, n), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 21), (2, k), jnp.float32)
    plane = PackedLinear.from_weights(w, pack_axis=-1).materialize_plane(
        2, extra_precision=True)
    assert plane.pack_axis == -1
    y = ops.plane_matmul(x, plane, use_kernel=True)
    ref = x @ quant.quant_dequant(w, 8, 2, axis=0, extra_precision=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_ep_decode_step_matches_dequant_on_interpret_kernel(served):
    """Full packed ep decode step == dequantized ep decode step."""
    params, cfg, _ = served
    cfg_k = cfg.replace(quant=dataclasses.replace(
        cfg.quant, packed_bits=2, packed_kernel=True))
    pp = materialize_packed_params(params, cfg_k, 2, extra_precision=True)
    up = pp["layers"]["ffn"]["up"]["w"]
    assert isinstance(up, PackedPlane) and up.extra_precision
    sp = materialize_served_params(params, cfg, 2, True)
    state = api.init_state(cfg, 2, 16)
    tok = jax.random.randint(jax.random.fold_in(KEY, 30), (2, 1), 0,
                             cfg.vocab_size)
    pos = jnp.asarray([3, 7], jnp.int32)
    lk, _ = api.decode_step_slots(pp, state, tok, pos, cfg_k, bits=None)
    ld, _ = api.decode_step_slots(sp, state, tok, pos, cfg, bits=None)
    np.testing.assert_allclose(np.asarray(lk), np.asarray(ld),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(lk, -1)),
                                  np.asarray(jnp.argmax(ld, -1)))


def test_moe_ep_decode_matches_dequant_on_interpret_kernel():
    """Packed ep on the MoE layout: expert-batched ep kernel for the
    K-packed up/gate stacks, ep jnp twin for the N-packed down."""
    cfg = get_config("granite_moe_1b_a400m").reduced()
    params = api.init(KEY, cfg)
    cfg_k = cfg.replace(quant=dataclasses.replace(
        cfg.quant, packed_bits=2, packed_kernel=True))
    pp = materialize_packed_params(params, cfg_k, 2, extra_precision=True)
    up = pp["layers"]["moe"]["up"]["w"]
    down = pp["layers"]["moe"]["down"]["w"]
    assert up.extra_precision and up.pack_axis == -2 and up.words.ndim == 4
    assert down.extra_precision and down.pack_axis == -1
    sp = materialize_served_params(params, cfg, 2, True)
    state = api.init_state(cfg, 2, 16)
    tok = jax.random.randint(jax.random.fold_in(KEY, 31), (2, 1), 0,
                             cfg.vocab_size)
    pos = jnp.asarray([3, 7], jnp.int32)
    lk, _ = api.decode_step_slots(pp, state, tok, pos, cfg_k, bits=None)
    ld, _ = api.decode_step_slots(sp, state, tok, pos, cfg, bits=None)
    np.testing.assert_allclose(np.asarray(lk), np.asarray(ld),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# effective bytes/bits == the analytic quant.py Table 7 accounting
# ---------------------------------------------------------------------------


def test_tier_bytes_and_effective_bits_match_table7_accounting(served):
    params, cfg, _ = served
    cfg4 = cfg.replace(num_layers=4)
    params4 = api.init(KEY, cfg4)
    cache = TierCache(params4, cfg4, packed=True)
    tiers = {t.name: t for t in default_tiers(cfg4.num_layers)}
    ep = cache.get(tiers["int2+ep"])
    assert ep.packed_bits == (2, "ep") == packed_rep_key(2, True)
    # stored bytes: 2-bit plane + dense 1-bit bitmap on every projection
    d, f, L = cfg4.d_model, cfg4.d_ff, cfg4.num_layers
    expected = L * (
        packing.packed_nbytes(d, f, 2, -2, extra_precision=True) * 2 +
        packing.packed_nbytes(f, d, 2, -1, extra_precision=True))
    assert ep.packed_nbytes == expected
    # measured effective bits == analytic Table 7 accounting over the
    # SAME parent codes each plane was sliced from: r + overflow frac
    parent = build_packed_parent(params4, cfg4)
    num = den = 0.0
    for pl in parent.values():
        codes = packing.unpack_codes(pl.words, 8, pl._packed_len,
                                     axis=pl.pack_axis)
        num += float(quant.effective_bits(codes, 8, 2)) * codes.size
        den += codes.size
    np.testing.assert_allclose(ep.effective_bits, num / den, rtol=1e-6)
    assert 2.0 <= ep.effective_bits <= 2.2
    # the bytes staircase is strict: int8 > int4 > mnm3.5 > int2+ep > int2
    ladder = [cache.get(t).packed_nbytes for t in default_tiers(L)]
    assert all(a > b for a, b in zip(ladder, ladder[1:]))


# ---------------------------------------------------------------------------
# mid-flight downgrade into int2+ep: exact, one compile per key
# ---------------------------------------------------------------------------


def _drive(sched, cfg, indices):
    rng = np.random.default_rng(11)
    for i in range(2):
        sched.submit(Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 8),
                             max_new_tokens=len(indices) + 1))
    for idx in indices:
        sched.router.index = idx
        sched.step()
    sched.router.index = 0
    return sched.run_until_idle()


def test_midflight_downgrade_into_int2_ep_no_recompile_on_revisit(served):
    params, cfg, eng = served
    switches = [0, 3, 4, 3, 0, 3]          # int8 -> int2+ep -> int2 -> ...
    sp = eng.scheduler(elastic=True, packed=True, cooldown=10_000)
    sd = eng.scheduler(elastic=True, packed=False, cooldown=10_000)
    rp = _drive(sp, cfg, switches)
    rd = _drive(sd, cfg, switches)
    # packed ep planes and dequantized ep weights decode the same tokens
    for uid in rd:
        np.testing.assert_array_equal(rp[uid], rd[uid])
    # one closure per representation: the ep rung keys (2, "ep"),
    # distinct from plain int2's 2 -- and revisiting either never
    # recompiled (exactly one decode trace per key)
    assert_no_recompiles(sp, require_keys={8, 2, (2, "ep")})
    assert_no_recompiles(sd, expect_keys={None})


def test_engine_packed_ep_generate_matches_dequant(served, monkeypatch):
    """The engine-level fixed tier: use_packed + extra_precision serves
    (no fallback) and generates the same tokens as the dequant ep path."""
    params, cfg, _ = served
    import repro.serve.engine as engine_mod
    monkeypatch.setattr(engine_mod, "_packed_backend_ok", lambda: True)
    eng = Engine(params, cfg, ServeConfig(bits=2, max_len=32, num_slots=2,
                                          page_size=8, use_packed=True,
                                          extra_precision=True))
    assert eng.packed and eng._packed_key == (2, "ep")
    prompts = jax.random.randint(jax.random.fold_in(KEY, 40), (2, 8), 0,
                                 cfg.vocab_size)
    out = np.asarray(eng.generate(prompts, 4))
    batch_sched = next(iter(eng._schedulers.values()))
    assert_no_recompiles(batch_sched, expect_keys={(2, "ep")})
    ref = Engine(params, cfg, ServeConfig(bits=2, max_len=32, num_slots=2,
                                          page_size=8, extra_precision=True))
    np.testing.assert_array_equal(out, np.asarray(ref.generate(prompts, 4)))
