"""Packed planes for MoE expert stacks and Mix'n'Match tiers, plus the
N-packed serving-path fixes: serve_linear honors the pack axis, packed
MoE decode equals the dequantized oracle through the expert-batched
interpret kernel, packed MnM tiers switch mid-flight without recompiles,
and per-tier packed bytes match the per-layer analytic sum."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import packing, quant
from repro.core.packing import PackedLinear, PackedPlane
from repro.kernels import ops
from repro.models import api
from repro.serve import (Engine, Request, ServeConfig, TierCache,
                         default_tiers, materialize_packed_params)
from repro.serve.engine import build_packed_parent

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def moe_served():
    cfg = get_config("granite_moe_1b_a400m").reduced()
    params = api.init(KEY, cfg)
    eng = Engine(params, cfg, ServeConfig(bits=8, max_len=32, num_slots=4,
                                          page_size=8))
    return params, cfg, eng


def _prompts(cfg, B, S, seed):
    return jax.random.randint(jax.random.fold_in(KEY, seed), (B, S), 0,
                              cfg.vocab_size)


# ---------------------------------------------------------------------------
# N-packed serving path (serve_linear / plane_matmul / packed_nbytes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4, 2])
def test_n_packed_serve_linear_matches_dequant_oracle(bits):
    """serve_linear on a pack_axis=-1 parent equals the dequant oracle;
    quant_matmul alone would read the (k, ceil(n/cpw)) words as K-packed."""
    k, n = 48, 40
    w = jax.random.normal(KEY, (k, n), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (3, k), jnp.float32)
    pl = PackedLinear.from_weights(w, pack_axis=-1)
    y = ops.serve_linear(x, pl, bits)
    ref = x @ quant.quant_dequant(w, 8, bits, axis=0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_n_packed_serve_linear_extra_precision_matches_oracle():
    k, n = 32, 24
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (k, n), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (2, k), jnp.float32)
    pl = PackedLinear.from_weights(w, pack_axis=-1)
    y = ops.serve_linear(x, pl, 2, extra_precision=True)
    ref = x @ quant.quant_dequant(w, 8, 2, axis=0, extra_precision=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_plane_matmul_uses_explicit_pack_axis_not_shape_heuristic():
    """A square N-packed plane (k == n) defeats any shape guess; the
    explicit pack_axis carried on PackedPlane routes it correctly."""
    k = n = 32
    bits = 4
    w = jax.random.normal(jax.random.fold_in(KEY, 4), (k, n), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (2, k), jnp.float32)
    ref = x @ quant.quant_dequant(w, 8, bits, axis=0)
    for pack_axis in (-2, -1):
        plane = PackedLinear.from_weights(w, pack_axis=pack_axis) \
            .materialize_plane(bits)
        assert plane.pack_axis == pack_axis
        y = ops.plane_matmul(x, plane, use_kernel=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


def test_packed_nbytes_honors_pack_axis():
    """The roofline byte count matches the actual word-array size on
    both axes, including ragged (non-multiple-of-cpw) packed dims."""
    k, n, bits = 5, 6, 4           # cpw = 8: both dims ragged
    codes = jnp.zeros((k, n), jnp.int32)
    for axis, pack_axis in ((0, -2), (1, -1)):
        words = packing.pack_codes(codes, bits, axis=axis)
        assert packing.packed_nbytes(k, n, bits, pack_axis) == \
            words.size * words.dtype.itemsize
    # K-packed default unchanged
    assert packing.packed_nbytes(k, n, bits) == \
        packing.packed_nbytes(k, n, bits, -2)


# ---------------------------------------------------------------------------
# packed MoE expert stacks
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4, 2])
def test_moe_packed_decode_matches_dequant_on_interpret_kernel(moe_served, bits):
    """Packed expert-stack decode (expert-batched Pallas kernel in
    interpret mode for up/gate, jnp twin for the N-packed down) equals
    the dequantized fake-quant decode step."""
    params, cfg, _ = moe_served
    cfg_k = cfg.replace(quant=dataclasses.replace(
        cfg.quant, packed_bits=bits, packed_kernel=True))
    pp = materialize_packed_params(params, cfg_k, bits)
    up = pp["layers"]["moe"]["up"]["w"]
    down = pp["layers"]["moe"]["down"]["w"]
    assert isinstance(up, PackedPlane) and up.pack_axis == -2
    assert isinstance(down, PackedPlane) and down.pack_axis == -1
    assert up.words.ndim == 4      # (L, E, ceil(k/cpw), n) expert stacks
    from repro.serve.engine import materialize_served_params
    sp = materialize_served_params(params, cfg, bits)
    state = api.init_state(cfg, 2, 16)
    tok = _prompts(cfg, 2, 1, seed=bits)
    pos = jnp.asarray([3, 7], jnp.int32)
    lk, _ = api.decode_step_slots(pp, state, tok, pos, cfg_k, bits=None)
    ld, _ = api.decode_step_slots(sp, state, tok, pos, cfg, bits=None)
    np.testing.assert_allclose(np.asarray(lk), np.asarray(ld),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(lk, -1)),
                                  np.asarray(jnp.argmax(ld, -1)))


def test_moe_generate_routes_through_scheduler_and_matches_legacy(moe_served):
    """MoE no longer detours to generate_legacy: the scheduler path is
    token-identical (row-local dispatch, ample reduced capacity)."""
    params, cfg, eng = moe_served
    prompts = _prompts(cfg, 3, 8, seed=6)
    out = np.asarray(eng.generate(prompts, 5))
    assert eng._schedulers                     # scheduler path was taken
    legacy = np.asarray(eng.generate_legacy(prompts, 5))
    np.testing.assert_array_equal(out, legacy)


def test_packed_parent_covers_moe_and_serves_no_raw_expert(moe_served):
    """Every scoped MoE projection has a packed parent plane, and the
    packed tier contains no raw bf16 expert stack (the old silent
    unquantized-expert hole)."""
    params, cfg, _ = moe_served
    parent = build_packed_parent(params, cfg)
    assert any("moe" in k and "up" in k for k in parent)
    assert any("moe" in k and "down" in k for k in parent)
    pp = materialize_packed_params(params, cfg, 4, parent=parent)
    for proj in ("up", "gate", "down"):
        assert isinstance(pp["layers"]["moe"][proj]["w"], PackedPlane)


def test_scoped_leaf_without_parent_serves_dequantized_and_warns(moe_served):
    """Satellite guard: a scoped projection missing from the packed
    parent is materialized dequantized at the tier's bits (with a
    warning), never raw bf16 -- and the resulting MIXED-representation
    MoE layer (dequantized up, packed gate/down) still decodes, equal to
    the fully dequantized tier (apply_moe dispatches per projection)."""
    params, cfg, _ = moe_served
    parent = build_packed_parent(params, cfg)
    dropped = next(k for k in parent if "moe" in k and "up" in k)
    parent = {k: v for k, v in parent.items() if k != dropped}
    cfg_k = cfg.replace(quant=dataclasses.replace(
        cfg.quant, packed_bits=2, packed_kernel=True))
    with pytest.warns(UserWarning, match="no packed parent"):
        pp = materialize_packed_params(params, cfg_k, 2, parent=parent)
    served = pp["layers"]["moe"]["up"]["w"]
    raw = params["layers"]["moe"]["up"]["w"]
    assert not isinstance(served, PackedPlane)
    assert isinstance(pp["layers"]["moe"]["gate"]["w"], PackedPlane)
    ref = quant.quant_dequant(raw, cfg.quant.parent_bits, 2, axis=2)
    np.testing.assert_allclose(np.asarray(served), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    from repro.serve.engine import materialize_served_params
    sp = materialize_served_params(params, cfg, 2)
    state = api.init_state(cfg, 2, 16)
    tok = _prompts(cfg, 2, 1, seed=12)
    pos = jnp.asarray([3, 7], jnp.int32)
    lk, _ = api.decode_step_slots(pp, state, tok, pos, cfg_k, bits=None)
    ld, _ = api.decode_step_slots(sp, state, tok, pos, cfg, bits=None)
    np.testing.assert_allclose(np.asarray(lk), np.asarray(ld),
                               rtol=1e-3, atol=1e-3)


def test_per_layer_fallback_matches_dequant_mnm_tier(moe_served):
    """The per-layer dequant fallback applies bits[l] per layer, exactly
    like the dequantized Mix'n'Match tier -- not a uniform max(bits)."""
    params, cfg, _ = moe_served
    parent = build_packed_parent(params, cfg)
    dropped = next(k for k in parent if "moe" in k and "up" in k)
    parent = {k: v for k, v in parent.items() if k != dropped}
    bits = [2, 4]
    with pytest.warns(UserWarning, match="no packed parent"):
        pp = materialize_packed_params(params, cfg, bits, parent=parent)
    from repro.serve.engine import materialize_served_params
    sp = materialize_served_params(params, cfg, bits)
    for l in range(len(bits)):
        served = pp["layers"][l]["moe"]["up"]["w"]
        assert not isinstance(served, PackedPlane)
        np.testing.assert_allclose(
            np.asarray(served),
            np.asarray(sp["layers"]["moe"]["up"]["w"][l]),
            rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# packed Mix'n'Match tiers: mid-flight switching, no recompile on revisit
# ---------------------------------------------------------------------------


def _drive(sched, cfg, indices, gen_extra=1):
    rng = np.random.default_rng(11)
    for i in range(2):
        sched.submit(Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 8),
                             max_new_tokens=len(indices) + gen_extra))
    for idx in indices:
        sched.router.index = idx
        sched.step()
    sched.router.index = 0
    return sched.run_until_idle()


def test_mnm_packed_tier_switch_no_recompile_and_exact(moe_served):
    """A packed Mix'n'Match tier serves mid-flight like any uniform
    tier: one lazily-warmed compiled closure keyed by the per-layer bits
    tuple, reused on revisit, token-identical to the dequantized path --
    on the MoE config, so expert stacks switch precision too."""
    params, cfg, eng = moe_served
    mnm = next(t for t in default_tiers(cfg.num_layers)
               if not isinstance(t.bits, int))
    switches = [0, 2, 3, 2, 0]             # int8 -> mnm -> int2+ep -> ...
    sp = eng.scheduler(elastic=True, packed=True, cooldown=10_000)
    sd = eng.scheduler(elastic=True, packed=False, cooldown=10_000)
    rp = _drive(sp, cfg, switches)
    rd = _drive(sd, cfg, switches)
    for uid in rd:
        np.testing.assert_array_equal(rp[uid], rd[uid])
    key = tuple(mnm.bits)
    assert key in sp._fns and set(sd._fns) == {None}
    # revisiting the MnM tier reused its closure: exactly one compile
    assert sp._fns[key]["decode"]._cache_size() == 1
    # and the MnM tier really served per-layer packed planes
    em = sp.tier_cache.get(mnm)
    assert em.packed_bits == key
    assert isinstance(em.params["layers"], list)


# ---------------------------------------------------------------------------
# per-tier packed bytes == per-layer analytic sum
# ---------------------------------------------------------------------------


def _expected_tier_nbytes(cfg, bits_per_layer, ep=False):
    """Sum packing.packed_nbytes over layers x projections (x experts)."""
    d, f = cfg.d_model, cfg.d_ff
    E = cfg.num_experts or 1
    total = 0
    for b in bits_per_layer:
        per_proj = (packing.packed_nbytes(d, f, b, -2,            # up, gate
                                          extra_precision=ep) * 2 +
                    packing.packed_nbytes(f, d, b, -1,            # down
                                          extra_precision=ep))    # (N-packed)
        total += E * per_proj
    return total


@pytest.mark.parametrize("arch", ["granite_moe_1b_a400m", "qwen3_1_7b"])
def test_per_tier_packed_nbytes_match_per_layer_sum(arch):
    # 4 layers so the Mix'n'Match tier (3.5 eff bits) sits strictly
    # between int4 and int2+ep's 3 stored bits/weight in the staircase
    cfg = get_config(arch).reduced().replace(num_layers=4)
    params = api.init(KEY, cfg)
    cache = TierCache(params, cfg, packed=True)
    entries = {t.name: (cache.get(t), t) for t in default_tiers(cfg.num_layers)}
    for name, (entry, tier) in entries.items():
        bits = ([tier.bits] * cfg.num_layers if isinstance(tier.bits, int)
                else list(tier.bits))
        assert entry.packed_nbytes == _expected_tier_nbytes(
            cfg, bits, ep=tier.extra_precision), name
    # strictly decreasing per the per-layer (stored) bit sum:
    # int8 > int4 > mnm3.5 > int2+ep > int2
    ordered = [e.packed_nbytes for e, t in
               sorted(entries.values(),
                      key=lambda et: -et[1].effective_bits)]
    assert all(a > b for a, b in zip(ordered, ordered[1:]))
