"""Matryoshka paged KV cache tests.

Acceptance surface of the paged refactor:

  * fp-KV paged serving is TOKEN-IDENTICAL to the dense slot-array
    path (dense and MoE families) -- the exactness gate that proves the
    page-table indirection is a pure layout change;
  * int8 KV pages attended at the 8/4/2-bit Matryoshka slices are
    bit-exact vs the dequantized-KV oracle built directly from
    `core.quant` (slice_bits on the MSB grid);
  * PagedPool edge cases: overcommit (free pages but no free slot, and
    the all-or-nothing page reservation), defrag with reserved-but-
    unwritten pages, free-then-readmit physical page reuse;
  * radix prefix sharing: refcounted read-only reuse, copy-on-write on
    a partial tail, LRU eviction under pressure, and token identity of
    prefix-hit admissions vs the cold oracle;
  * paged self-speculative decoding stays token-exact (the masked
    stale-row rewind);
  * the ServeMetrics `kv` section: bytes/token staircase, occupancy,
    and the prefix hit-rate / hit-vs-cold TTFT split.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import quant
from repro.models import api, attention as attn
from repro.runtime.compile_guard import assert_no_recompiles
from repro.serve import (Engine, KVCacheConfig, PagedPool, Request,
                         ServeConfig, SpecDecodeConfig)
from repro.serve.kv_cache import kv_bits_for_rep

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("qwen3_1_7b").reduced()
    return cfg, api.init(KEY, cfg)


@pytest.fixture(scope="module")
def moe():
    cfg = get_config("granite_moe_1b_a400m").reduced()
    return cfg, api.init(KEY, cfg)


def _prompts(cfg, B, S, seed=1):
    return jax.random.randint(jax.random.fold_in(KEY, seed), (B, S), 0,
                              cfg.vocab_size)


def _engine(cfg, params, **kv_kw):
    return Engine(params, cfg, ServeConfig(bits=4, max_len=32, num_slots=2,
                                           page_size=8, **kv_kw))


# ---------------------------------------------------------------------------
# exactness gates: fp pages == dense, sliced views == quant oracle
# ---------------------------------------------------------------------------


def test_paged_fp_token_identical_dense(dense):
    cfg, params = dense
    prompts = _prompts(cfg, 3, 16)
    ref = np.asarray(_engine(cfg, params).generate(prompts, 8))
    paged = np.asarray(_engine(cfg, params, kv_bits="fp").generate(prompts, 8))
    np.testing.assert_array_equal(ref, paged)


def test_paged_fp_token_identical_off_bucket_lengths(dense):
    """Prompt lengths off the page/bucket grid still match exactly."""
    cfg, params = dense
    prompts = _prompts(cfg, 2, 13, seed=9)
    ref = np.asarray(_engine(cfg, params).generate(prompts, 6))
    paged = np.asarray(_engine(cfg, params, kv_bits="fp").generate(prompts, 6))
    np.testing.assert_array_equal(ref, paged)


def test_paged_fp_token_identical_moe(moe):
    cfg, params = moe
    prompts = _prompts(cfg, 2, 16, seed=3)
    ref = np.asarray(_engine(cfg, params).generate(prompts, 6))
    paged = np.asarray(_engine(cfg, params, kv_bits="fp").generate(prompts, 6))
    np.testing.assert_array_equal(ref, paged)


def test_quantized_kv_rows_match_slice_oracle():
    """int8 KV pages read at r bits == the core.quant oracle, bit-exact:
    x_hat = alpha * slice_bits(q8, 8, r) - alpha*z for every r."""
    x = jax.random.normal(jax.random.fold_in(KEY, 11), (4, 16, 2, 8),
                          jnp.float32) * 3.0
    codes, alpha, beta = attn.quant_kv_rows(x)
    q8, a_ref, z_ref = quant.quantize(x, attn.KV_PARENT_BITS, axis=-1)
    np.testing.assert_array_equal(np.asarray(codes),
                                  np.asarray(q8).astype(np.uint8))
    for r in (8, 4, 2):
        got = attn.dequant_kv_rows(codes, alpha, beta, r, jnp.float32)
        sl = quant.slice_bits(q8, attn.KV_PARENT_BITS, r)
        want = (a_ref * sl.astype(jnp.float32)
                - a_ref * z_ref.astype(jnp.float32)).astype(jnp.float32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # r == 8 recovers the parent dequant (Matryoshka MSB nesting) up to
    # one float-associativity ulp: a*q - (a*z) vs a*(q - z)
    full = attn.dequant_kv_rows(codes, alpha, beta, 8, jnp.float32)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(quant.dequantize(q8, a_ref, z_ref)),
                               rtol=1e-5, atol=1e-5)


def test_gather_slot_view_dequantizes_through_page_table():
    """write_pages -> gather_slot_view round-trips the sliced dequant
    through a shuffled page table, bit-exact vs the row oracle."""
    cfg = get_config("qwen3_1_7b").reduced()
    kh, hd, T = cfg.num_kv_heads, cfg.resolved_head_dim, 4
    cache = attn.init_paged_cache(cfg, num_pages=6, page_size=T,
                                  layers=None, kv_bits=8, dtype=jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(KEY, 21), (2, 8, kh, hd),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 22), (2, 8, kh, hd),
                          jnp.float32)
    # slot 0 -> pages [5, 1], slot 1 -> pages [3, 0] (deliberately
    # non-contiguous, non-monotone physical placement)
    ptab = jnp.asarray([[5, 1], [3, 0]], jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(8)[None, :], (2, 8))
    pids = jnp.take_along_axis(ptab, pos // T, axis=1)
    rows = pos % T
    cache = attn.write_pages(cache, k, v, pids, rows)
    for r in (8, 4, 2):
        k_view, _ = attn.gather_slot_view(cache, ptab, kv_bits=r,
                                          dtype=jnp.float32)
        codes, alpha, beta = attn.quant_kv_rows(k)
        want_k = attn.dequant_kv_rows(codes, alpha, beta, r, jnp.float32)
        np.testing.assert_array_equal(np.asarray(k_view),
                                      np.asarray(want_k))


def test_paged_quant_bits_degrade_gracefully(dense):
    """int8/int4 KV attend matches fp on a short horizon for this tiny
    model; int2 runs and emits valid tokens (lossy by design)."""
    cfg, params = dense
    prompts = _prompts(cfg, 2, 16)
    ref = np.asarray(_engine(cfg, params, kv_bits="fp").generate(prompts, 4))
    for kvb in (8, "auto"):
        out = np.asarray(_engine(cfg, params, kv_bits=kvb).generate(prompts, 4))
        np.testing.assert_array_equal(ref, out)
    out2 = np.asarray(_engine(cfg, params, kv_bits=2).generate(prompts, 4))
    assert out2.shape == ref.shape
    assert ((0 <= out2) & (out2 < cfg.vocab_size)).all()


def test_kv_bits_for_rep_mapping():
    assert kv_bits_for_rep(None) == 8           # dequantized tier
    assert kv_bits_for_rep(8) == 8
    assert kv_bits_for_rep(4) == 4
    assert kv_bits_for_rep(2) == 2
    assert kv_bits_for_rep((8, 4, 2, 2)) == 4   # Mix'n'Match tuple
    assert kv_bits_for_rep((2, "ep")) == 2      # extra-precision wrapper
    assert kv_bits_for_rep(((8, 4, 2, 2), "ep")) == 4
    with pytest.raises(ValueError):
        KVCacheConfig(kv_bits=3)


# ---------------------------------------------------------------------------
# PagedPool overcommit edge cases (satellite)
# ---------------------------------------------------------------------------


def test_overcommit_free_pages_but_no_free_slot():
    pool = PagedPool(num_slots=2, page_size=4, pages_per_slot=4,
                     total_pages=32)
    assert pool.admit("a", [1, 2], 8) is not None
    assert pool.admit("b", [3, 4], 8) is not None
    assert pool.free_pages > 0
    assert pool.admit("c", [5, 6], 8) is None   # slots, not pages, bind
    pool.free(0)
    assert pool.admit("c", [5, 6], 8) is not None


def test_overcommit_page_reservation_is_all_or_nothing():
    pool = PagedPool(num_slots=4, page_size=4, pages_per_slot=4,
                     total_pages=5)
    got = pool.admit("a", [1], 16)              # 4 pages
    assert got is not None
    before = pool.free_pages
    assert pool.admit("b", [2], 16) is None     # needs 4, only 1 free
    assert pool.free_pages == before            # nothing leaked
    assert pool.admit("b", [2], 4) is not None  # 1 page still fits


def test_free_then_readmit_reuses_physical_pages():
    pool = PagedPool(num_slots=2, page_size=4, pages_per_slot=2,
                     total_pages=4)
    s0, _, _ = pool.admit("a", [1, 2], 8)
    s1, _, _ = pool.admit("b", [3, 4], 8)
    assert pool.free_pages == 0                  # pool fully committed
    freed = set(pool.slot_pages[s0])
    pool.free(s0)
    # the only free pages are the freed ones: readmission must reuse
    # exactly those physical ids (released pages really return)
    s2, _, _ = pool.admit("c", [5, 6], 8)
    assert set(pool.slot_pages[s2]) == freed
    assert pool.page_table()[s2, 0] in freed
    assert pool.free_pages == 0


def test_defrag_with_reserved_but_unwritten_pages():
    pool = PagedPool(num_slots=3, page_size=4, pages_per_slot=4,
                     total_pages=16)
    s0, _, _ = pool.admit("a", [1], 16)          # 4 pages reserved
    s1, _, _ = pool.admit("b", [2], 16)
    pool.grow(s1, 2)                             # 1 of 4 pages written
    assert pool.written_pages == 1               # reserved != written
    assert pool.used_pages == 8
    pages_b = list(pool.slot_pages[s1])
    pool.free(s0)
    perm, moves = pool.defrag()
    new_slot = moves[s1]
    assert new_slot == 0                         # compacted to the front
    assert pool.slot_pages[new_slot] == pages_b  # physical pages stay put
    tab = pool.page_table()
    assert list(tab[new_slot][:4]) == pages_b
    assert (tab[1:] == pool.total_pages).all()   # holes carry the sentinel
    assert pool.used_pages == 4


# ---------------------------------------------------------------------------
# prefix sharing: refcounts, COW, eviction
# ---------------------------------------------------------------------------


def test_prefix_match_refcount_and_cow():
    pool = PagedPool(num_slots=3, page_size=8, pages_per_slot=4,
                     total_pages=32, prefix_cache=True)
    prompt = list(range(100, 120))               # 2.5 pages
    s0, shared0, cow0 = pool.admit("a", prompt, 24)
    assert shared0 == 0 and cow0 == []           # cold
    pool.grow(s0, len(prompt))
    pool.register_prefix(s0, prompt)
    s1, shared1, cow1 = pool.admit("b", prompt, 24)
    # match = 2 full pages + the partial tail, capped at len-1
    assert shared1 == len(prompt) - 1
    assert len(cow1) == 1                        # tail page copy-on-write
    src, dst = cow1[0]
    assert src == pool.slot_pages[s0][2]         # shared tail original
    assert dst == pool.slot_pages[s1][2]         # b's own fresh copy
    # the two full prefix pages are physically shared, refcount > 1
    assert pool.slot_pages[s1][:2] == pool.slot_pages[s0][:2]
    for pid in pool.slot_pages[s1][:2]:
        assert pool._refs[pid] >= 3              # a + b + index entry
    assert pool.prefix_hits == 1 and pool.prefix_shared_tokens == shared1
    # freeing the cold owner keeps the shared pages alive for b + index
    pool.free(s0)
    for pid in pool.slot_pages[s1][:2]:
        assert pool._refs[pid] == 2


def test_prefix_entries_evicted_lru_when_pool_runs_dry():
    pool = PagedPool(num_slots=2, page_size=4, pages_per_slot=4,
                     total_pages=5, prefix_cache=True)
    prompt = list(range(7))                      # 1 full page + 3-token tail
    s0, _, _ = pool.admit("a", prompt, 8)
    pool.grow(s0, len(prompt))
    pool.register_prefix(s0, prompt)
    pool.free(s0)
    assert len(pool._prefix) == 2                # page chain + tail
    assert pool.used_pages == 2                  # held only by the index
    # 3 free pages, a 4-page request: the CHILDLESS tail entry is
    # evicted to cover it, the full-page chain node (still a parent
    # until the tail goes) survives
    s1, shared, _ = pool.admit("b", list(range(50, 54)), 16)
    assert s1 is not None and shared == 0
    assert pool.free_pages == 0
    assert len(pool._prefix) == 1
    assert next(iter(pool._prefix.values())).full


def test_prefix_hit_admissions_token_identical(dense):
    """Prefix-hit suffix prefill emits the same tokens as cold serving,
    and the metrics kv section reports the hits."""
    cfg, params = dense

    def run(prefix_cache):
        eng = Engine(params, cfg, ServeConfig(
            bits=4, max_len=48, num_slots=2, page_size=8, kv_bits="fp",
            prefix_cache=prefix_cache))
        sched = eng.scheduler(num_slots=2, max_len=48)
        rng = np.random.default_rng(3)
        shared = rng.integers(0, cfg.vocab_size, size=24)
        for i in range(4):
            suffix = rng.integers(0, cfg.vocab_size, size=8)
            sched.submit(Request(uid=i,
                                 prompt=np.concatenate([shared, suffix]),
                                 max_new_tokens=4))
            res = sched.run_until_idle()     # sequential: later ones hit
        return res, sched.metrics.summary()["kv"]

    cold_res, cold_kv = run(False)
    hit_res, hit_kv = run(True)
    for uid in cold_res:
        np.testing.assert_array_equal(cold_res[uid], hit_res[uid])
    assert cold_kv["prefix_hits"] == 0
    assert hit_kv["prefix_hits"] == 3 and hit_kv["prefix_hit_rate"] == 0.75
    assert hit_kv["shared_prefix_tokens"] > 0


# ---------------------------------------------------------------------------
# scheduler integration: spec decode, metrics, elastic auto width
# ---------------------------------------------------------------------------


def test_paged_spec_decode_token_exact(dense):
    cfg, params = dense
    prompts = _prompts(cfg, 2, 16)
    spec = SpecDecodeConfig(draft_bits=2, draft_len=3)
    eng_d = Engine(params, cfg, ServeConfig(bits=4, max_len=40, num_slots=2,
                                            page_size=8))
    eng_p = Engine(params, cfg, ServeConfig(bits=4, max_len=40, num_slots=2,
                                            page_size=8, kv_bits="fp"))
    plain = np.asarray(eng_d.generate(prompts, 8))
    spec_paged = np.asarray(eng_p.generate(prompts, 8, spec_decode=spec))
    np.testing.assert_array_equal(plain, spec_paged)
    sm = next(iter(eng_p._schedulers.values())).metrics.summary()
    assert sm["spec"]["rounds"] > 0
    assert sm["kv"]["kv_bits"] == "fp"


def test_metrics_kv_section_and_bytes_staircase(dense):
    cfg, params = dense
    eng = _engine(cfg, params, kv_bits=8)
    out = eng.generate(_prompts(cfg, 2, 16), 4)
    assert out.shape == (2, 4)
    kv = next(iter(eng._schedulers.values())).metrics.summary()["kv"]
    assert kv["kv_bits"] == 8 and not kv["prefix_cache"]
    assert kv["total_pages"] > 0
    assert 0 < kv["peak_pages_written"] <= kv["peak_pages_reserved"]
    assert kv["peak_pages_reserved"] <= kv["total_pages"]
    # per-token KV read bytes: fp > int8 > int4 > int2, strictly
    sizes = [KVCacheConfig(kv_bits=b).bytes_per_token(cfg)
             for b in ("fp", 8, 4, 2)]
    assert all(a > b for a, b in zip(sizes, sizes[1:]))
    assert kv["bytes_per_token"] == sizes[1]
    # dense-mode schedulers report an empty kv section
    kv_dense = _engine(cfg, params).scheduler().metrics.summary()["kv"]
    assert kv_dense == {}


def test_elastic_auto_kv_width_compiles_per_rep(dense):
    """kv_bits='auto' ties the attend slice to the weight tier: each
    visited (representation, kv width) pair compiles exactly one closure
    set, and revisits reuse it."""
    cfg, params = dense
    eng = Engine(params, cfg, ServeConfig(bits=8, max_len=32, num_slots=2,
                                          page_size=8, kv_bits="auto"))
    sched = eng.scheduler(elastic=True, packed=False,
                          thresholds=(1, 4, 8, 16), cooldown=1)
    rng = np.random.default_rng(0)
    for i in range(6):
        sched.submit(Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 8),
                             max_new_tokens=3))
    res = sched.run_until_idle()
    assert len(res) == 6
    keys = [k for k in sched._fns if isinstance(k, tuple) and "kv" in k]
    assert keys and len(keys) == len(set(keys))
    for k in keys:                       # dequantized tiers read full int8
        assert k[-1] == 8
    # every visited (rep, kv-width) closure set compiled exactly once
    assert_no_recompiles(sched, require_keys=set(keys))
