"""Tests pinning the §Perf optimizations to the paper-faithful math:
packed serving planes, vmap-over-precisions loss, remat policies,
serving sharding rules."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.models import api
from repro.serve.engine import (materialize_packed_params,
                                materialize_served_params, packed_axes)
from repro.train.qat import make_loss_fn

KEY = jax.random.PRNGKey(0)


def _cfg(arch="qwen3_1_7b", **kw):
    return get_config(arch).reduced().replace(**kw)


def _batch(cfg, B=2, S=16):
    return {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.fold_in(KEY, 1), (B, S), 0,
                                     cfg.vocab_size),
    }


@pytest.mark.parametrize("bits", [8, 4, 2])
def test_packed_serving_equals_served(bits):
    cfg = _cfg()
    params = api.init(KEY, cfg)
    batch = {"tokens": _batch(cfg)["tokens"]}
    cfg_p = cfg.replace(quant=dataclasses.replace(cfg.quant, packed_bits=bits))
    pp = materialize_packed_params(params, cfg_p, bits)
    lp, _ = api.forward(pp, batch, cfg_p, bits=None)
    sp = materialize_served_params(params, cfg, bits)
    ls, _ = api.forward(sp, batch, cfg, bits=None)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(ls),
                               rtol=1e-3, atol=1e-3)


def test_packed_down_projection_packs_along_n():
    """down/wo projections pack along N so their K dim keeps TP sharding."""
    cfg = _cfg()
    cfg_p = cfg.replace(quant=dataclasses.replace(cfg.quant, packed_bits=4))
    params = api.init(KEY, cfg)
    pp = materialize_packed_params(params, cfg_p, 4)
    up = pp["layers"]["ffn"]["up"]["w"]
    down = pp["layers"]["ffn"]["down"]["w"]
    K, N = cfg.d_model, cfg.d_ff
    assert up.words.shape[-2] * 8 == K             # packed along K
    assert down.words.shape[-2] == N               # packed along N
    ax = packed_axes(api.axes(cfg), jax.eval_shape(
        lambda k: materialize_packed_params(api.init(k, cfg_p), cfg_p, 4), KEY),
        cfg_p)
    assert ax["layers"]["ffn"]["down"]["w"].words[-2] == "mlp"
    assert ax["layers"]["ffn"]["up"]["w"].words[-1] == "mlp"


def test_packed_bytes_shrink_with_bits():
    cfg = _cfg()
    params = api.init(KEY, cfg)
    def nbytes(bits):
        cfg_p = cfg.replace(quant=dataclasses.replace(cfg.quant, packed_bits=bits))
        pp = materialize_packed_params(params, cfg_p, bits)
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(pp["layers"]["ffn"]))
    n8, n4, n2 = nbytes(8), nbytes(4), nbytes(2)
    assert n8 > n4 > n2


@pytest.mark.parametrize("codistill", [(), ((8, 2),)])
def test_vmap_precisions_loss_and_grads_match(codistill):
    cfg = _cfg(num_layers=2).replace(
        quant=QuantConfig(mode="qat", bitwidths=(8, 4, 2),
                          weights=(0.1, 0.1, 1.0), codistill=codistill))
    params = api.init(KEY, cfg)
    batch = _batch(cfg)
    l_seq, m_seq = make_loss_fn(cfg)(params, batch)
    l_vm, m_vm = make_loss_fn(cfg, vmap_precisions=True)(params, batch)
    assert abs(float(l_seq) - float(l_vm)) < 1e-4
    for k in ("ce_int8", "ce_int2"):
        assert abs(float(m_seq[k]) - float(m_vm[k])) < 1e-4
    g1 = jax.grad(lambda p: make_loss_fn(cfg)(p, batch)[0])(params)
    g2 = jax.grad(lambda p: make_loss_fn(cfg, vmap_precisions=True)(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_vmap_precisions_moe_aux():
    cfg = get_config("granite_moe_1b_a400m").reduced().replace(
        num_layers=2, quant=QuantConfig(mode="qat"))
    params = api.init(KEY, cfg)
    batch = _batch(cfg)
    l, m = make_loss_fn(cfg, vmap_precisions=True)(params, batch)
    assert "moe_aux" in m and bool(jnp.isfinite(l))


@pytest.mark.parametrize("remat", ["", "block", "dots"])
def test_remat_policies_same_forward(remat):
    cfg = _cfg(remat=remat)
    params = api.init(KEY, cfg)
    batch = {"tokens": _batch(cfg)["tokens"]}
    logits, _ = api.forward(params, batch, cfg, bits=8)
    cfg0 = _cfg(remat="")
    ref, _ = api.forward(params, batch, cfg0, bits=8)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_serving_rules_drop_fsdp():
    from repro.runtime import sharding as shard
    rules = shard.serving_rules()
    assert rules["embed"] == []
    assert shard.RULES["embed"] == [("data",)]  # training rules untouched


def test_grouped_attention_matches_repeated_reference():
    """The grouped-GQA einsum equals explicit head repetition."""
    from repro.models import attention as attn
    B, S, H, KH, D = 2, 8, 4, 2, 16
    q = jax.random.normal(KEY, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KH, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KH, D))
    out = attn.causal_attention(q, k, v, chunk=4)
    k_rep = jnp.repeat(k, H // KH, axis=2)
    v_rep = jnp.repeat(v, H // KH, axis=2)
    scale = D ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k_rep) * scale
    mask = jnp.tril(jnp.ones((S, S), bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(logits, -1), v_rep)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
