"""Property-based tests (hypothesis) for system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st

from repro.core import mixnmatch, packing, quant

_settings = settings(max_examples=40, deadline=None)


@_settings
@given(
    st.integers(0, 2**31 - 1).map(np.uint32),
    st.sampled_from([1, 2, 4, 8]),
    st.integers(1, 200),
)
def test_pack_unpack_roundtrip(seed, bits, n):
    rng = np.random.default_rng(int(seed))
    codes = rng.integers(0, 2**bits, size=(n, 3), dtype=np.int32)
    words = packing.pack_codes(jnp.asarray(codes), bits, axis=0)
    back = packing.unpack_codes(words, bits, n, axis=0)
    np.testing.assert_array_equal(np.asarray(back), codes)


@_settings
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 3, 4, 6]))
def test_slice_bounds_and_grid(seed, r):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(0, 256, size=64, dtype=np.int32))
    s = np.asarray(quant.slice_bits(q, 8, r))
    shift = 2 ** (8 - r)
    assert s.min() >= 0 and s.max() <= (2**r - 1) * shift
    assert (s % shift == 0).all()


@_settings
@given(st.integers(0, 2**31 - 1))
def test_slice_monotone_nonexpansive(seed):
    """Slicing is monotone: q1 <= q2 implies S(q1) <= S(q2)."""
    rng = np.random.default_rng(seed)
    a = np.sort(rng.integers(0, 256, size=32).astype(np.int32))
    s = np.asarray(quant.slice_bits(jnp.asarray(a), 8, 2))
    assert (np.diff(s) >= 0).all()


@_settings
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4, 8]))
def test_quant_dequant_error_bounded_by_grid(seed, c):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(128, 4)).astype(np.float32))
    q, alpha, z = quant.quantize(w, c, axis=0)
    w_hat = quant.dequantize(q, alpha, z)
    err = np.asarray(jnp.abs(w - w_hat))
    bound = np.asarray(alpha)[0] * 0.5 + 1e-5
    assert (err <= bound[None, :]).all()


@_settings
@given(st.integers(0, 2**31 - 1))
def test_ste_gradient_identity_everywhere(seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(32, 4)).astype(np.float32))
    g = jax.grad(lambda w: quant.fake_quant(w, 8, 2).sum())(w)
    np.testing.assert_allclose(np.asarray(g), 1.0)


@_settings
@given(st.integers(4, 96), st.floats(2.0, 8.0),
       st.sampled_from(list(mixnmatch.STRATEGIES)))
def test_mixnmatch_budget_hit(L, target, strategy):
    a = mixnmatch.assign(L, target, strategy)
    assert len(a) == L
    assert set(a) <= {2, 4, 8}
    # greedy count split gets within half a bucket of the budget
    assert abs(mixnmatch.effective_bits(a) - target) <= 6.0 / L + 0.51


@_settings
@given(st.integers(6, 60))
def test_pyramid_center_heavier_than_ends(L):
    a = mixnmatch.assign(L, 5.0, "pyramid")
    assert a[L // 2] >= a[0]
    assert a[L // 2] >= a[-1]


@_settings
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4]))
def test_extra_precision_never_clamps_information(seed, r):
    """EP slicing is plain rounding: |S_ep(q)/2^(c-r) - q/2^(c-r)| <= 0.5."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(0, 256, size=64, dtype=np.int32))
    s = np.asarray(quant.slice_bits(q, 8, r, extra_precision=True))
    shift = 2 ** (8 - r)
    assert (np.abs(s - np.asarray(q)) <= shift // 2).all()


@_settings
@given(st.integers(0, 2**31 - 1))
def test_packed_linear_materialize_consistent(seed):
    """PackedLinear.materialize(r) == core quant_dequant at r bits."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
    pl = packing.PackedLinear.from_weights(w)
    for r in (2, 4, 8):
        words, alpha, beta = pl.materialize(r)
        codes = packing.unpack_codes(words, r, 64, axis=0)
        w_hat = alpha * codes.astype(jnp.float32) - beta
        expect = quant.quant_dequant(w, 8, r, axis=0)
        np.testing.assert_allclose(np.asarray(w_hat), np.asarray(expect),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# fleet router invariants (serve/fleet.py)
# ---------------------------------------------------------------------------

from repro.serve.router import FleetRouter, default_tiers  # noqa: E402


@_settings
@given(st.integers(0, 2**31 - 1), st.integers(1, 5))
def test_fleet_assignments_monotone_in_load(seed, num_replicas):
    """For any fixed fill order, rising load only deepens assignments."""
    rng = np.random.default_rng(seed)
    router = FleetRouter(default_tiers(4), num_replicas, pinned=(0,))
    order = [int(r) for r in rng.permutation(num_replicas)]
    prev = router.desired_indices(0.0, order)
    for load in np.cumsum(rng.uniform(0.0, 7.0, size=30)):
        cur = router.desired_indices(float(load), order)
        assert all(c >= p for c, p in zip(cur, prev)), (load, prev, cur)
        prev = cur


@_settings
@given(st.integers(0, 2**31 - 1), st.integers(2, 4), st.integers(1, 4))
def test_fleet_recovery_never_skips_a_rung(seed, num_replicas, cooldown):
    """Under ANY load sequence a replica recovers one rung at a time --
    int2 always passes through int2+ep on the way back up."""
    rng = np.random.default_rng(seed)
    router = FleetRouter(default_tiers(4), num_replicas, pinned=(0,),
                         cooldown=cooldown)
    prev = list(router.indices)
    for _ in range(120):
        router.observe(float(rng.uniform(0.0, 60.0)),
                       [float(x) for x in rng.uniform(0.0, 10.0,
                                                      size=num_replicas)])
        for p, c in zip(prev, router.indices):
            assert c - p >= -1, (prev, router.indices)
        prev = list(router.indices)


@_settings
@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
def test_fleet_mean_bits_non_increasing_under_rising_load(seed,
                                                          num_replicas):
    """Monotone budget + sticky deepest-first fill order: while the
    global load rises, the fleet-wide mean effective bits never rise."""
    rng = np.random.default_rng(seed)
    router = FleetRouter(default_tiers(4), num_replicas, pinned=(0,))
    bits = []
    for load in np.cumsum(rng.uniform(0.0, 5.0, size=40)):
        router.observe(float(load),
                       [float(x) for x in rng.uniform(0.0, 10.0,
                                                      size=num_replicas)])
        bits.append(router.mean_effective_bits())
    assert all(b2 <= b1 + 1e-9 for b1, b2 in zip(bits, bits[1:])), bits
