"""Unit tests for the paper's quantization math (Eqs. 1, 3, 6, 8)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.quant import QuantConfig


class TestSliceBits:
    """Appendix A / Errata worked examples, verbatim."""

    def test_paper_example_234(self):
        # 234 -> round 4 -> clamp 3 -> 3*64 = 192
        q = jnp.array([234], jnp.int32)
        assert int(quant.slice_bits(q, 8, 2)[0]) == 192

    def test_paper_example_53_rounds_up(self):
        # 53 = 0b00110101: MSBs 00, 3rd bit set -> round UP to 1 -> 64
        q = jnp.array([53], jnp.int32)
        assert int(quant.slice_bits(q, 8, 2)[0]) == 64

    def test_paper_example_240_clamped(self):
        # 240 rounds to 4, clamp -> 3 -> 192
        q = jnp.array([240], jnp.int32)
        assert int(quant.slice_bits(q, 8, 2)[0]) == 192

    def test_errata_extra_bucket_234(self):
        # Eq. 8 (no clamp): 234 -> 4 * 64 = 256, the 2^r+1-th bucket
        q = jnp.array([234], jnp.int32)
        assert int(quant.slice_bits(q, 8, 2, extra_precision=True)[0]) == 256

    def test_int2_codes_cover_paper_grid(self):
        # MatQuant int2 allows exactly {0, 64, 128, 192}
        q = jnp.arange(256, dtype=jnp.int32)
        vals = set(np.asarray(quant.slice_bits(q, 8, 2)).tolist())
        assert vals == {0, 64, 128, 192}

    def test_slice_full_width_identity(self):
        q = jnp.arange(256, dtype=jnp.int32)
        np.testing.assert_array_equal(quant.slice_bits(q, 8, 8), q)

    def test_dynamic_r_matches_static(self):
        q = jnp.arange(256, dtype=jnp.int32)
        for r in (2, 3, 4, 6, 8):
            np.testing.assert_array_equal(
                quant.slice_bits(q, 8, jnp.asarray(r)),
                quant.slice_bits(q, 8, r))

    def test_slice_under_jit_and_scan(self):
        q = jnp.arange(256, dtype=jnp.int32)

        def body(c, r):
            return c, quant.slice_bits(q, 8, r)

        _, outs = jax.lax.scan(body, None, jnp.array([2, 4, 8]))
        np.testing.assert_array_equal(outs[0], quant.slice_bits(q, 8, 2))
        np.testing.assert_array_equal(outs[2], q)


class TestMinMaxQuant:
    def test_roundtrip_error_bound(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (256, 16))
        for c in (2, 4, 8):
            q, alpha, z = quant.quantize(w, c, axis=0)
            w_hat = quant.dequantize(q, alpha, z)
            # max error <= alpha/2 per group
            err = jnp.max(jnp.abs(w - w_hat), axis=0)
            assert bool(jnp.all(err <= alpha[0] * 0.5 + 1e-6)), c

    def test_codes_in_range(self):
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 8)) * 100
        q, _, _ = quant.quantize(w, 4, axis=0)
        assert int(q.min()) >= 0 and int(q.max()) <= 15

    def test_constant_group_no_nan(self):
        w = jnp.ones((32, 4))
        q, alpha, z = quant.quantize(w, 8, axis=0)
        w_hat = quant.dequantize(q, alpha, z)
        assert bool(jnp.isfinite(w_hat).all())

    def test_extremes_hit_min_max(self):
        w = jax.random.normal(jax.random.PRNGKey(2), (128, 4))
        q, _, _ = quant.quantize(w, 8, axis=0)
        assert int(q.max()) == 255 and int(q.min()) == 0


class TestSTE:
    def test_identity_gradient(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 8))
        g = jax.grad(lambda w: jnp.sum(quant.fake_quant(w, 8, 2) * 3.0))(w)
        np.testing.assert_allclose(np.asarray(g), 3.0)

    def test_forward_matches_quant_dequant(self):
        # w + sg(qdq - w) == qdq up to one float-add rounding
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 8))
        np.testing.assert_allclose(
            np.asarray(quant.fake_quant(w, 8, 4)),
            np.asarray(quant.quant_dequant(w, 8, 4)), rtol=0, atol=1e-6)

    def test_omni_fake_quant_grads_flow_to_gamma_beta(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 8))
        gamma = jnp.ones((1, 8))
        beta = jnp.ones((1, 8))

        def loss(gamma, beta):
            return jnp.sum(quant.fake_quant_omni(w, 8, 2, gamma, beta) ** 2)

        g1, g2 = jax.grad(loss, argnums=(0, 1))(gamma, beta)
        assert float(jnp.abs(g1).sum()) > 0
        assert float(jnp.abs(g2).sum()) > 0


class TestExtraPrecision:
    def test_effective_bits_close_to_paper(self):
        # paper reports ~2.05 avg bits for int2 with the extra bucket
        w = jax.random.normal(jax.random.PRNGKey(0), (4096, 64))
        q, _, _ = quant.quantize(w, 8, axis=0)
        eff = float(quant.effective_bits(q, 8, 2))
        assert 2.0 < eff < 2.2, eff

    def test_ep_reduces_quant_error_at_int2(self):
        w = jax.random.normal(jax.random.PRNGKey(3), (1024, 32))
        base = quant.quant_dequant(w, 8, 2)
        ep = quant.quant_dequant(w, 8, 2, extra_precision=True)
        assert float(jnp.mean((ep - w) ** 2)) <= float(jnp.mean((base - w) ** 2))


class TestQuantConfig:
    def test_weight_length_validation(self):
        with pytest.raises(ValueError):
            QuantConfig(bitwidths=(8, 4, 2), weights=(1.0,))

    def test_bits_exceed_parent(self):
        with pytest.raises(ValueError):
            QuantConfig(bitwidths=(16,), weights=(1.0,), parent_bits=8)

    def test_lambdas(self):
        q = QuantConfig(bitwidths=(8, 2), weights=(0.1, 1.0))
        assert q.lambdas == {8: 0.1, 2: 1.0}


def test_right_shift_stat_orders_matquant_style():
    """Fig 1c: on the same value range, a distribution with more mass in
    the high buckets has a larger mean quantized code."""
    rng = np.random.default_rng(0)
    uniform = jnp.asarray(rng.uniform(0, 1, (1024, 8)).astype(np.float32))
    skewed = jnp.asarray(rng.beta(5.0, 1.0, (1024, 8)).astype(np.float32))
    # pin the ranges so minmax normalization is identical
    uniform = uniform.at[0].set(0.0).at[1].set(1.0)
    skewed = skewed.at[0].set(0.0).at[1].set(1.0)
    assert float(quant.right_shift_stat(skewed)) > float(quant.right_shift_stat(uniform))
