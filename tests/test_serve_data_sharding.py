"""Serving engine, data pipeline determinism, sharding-rule resolver."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.data import DataConfig, SyntheticCorpus, host_sharded_batches
from repro.models import api
from repro.runtime import sharding as shard
from repro.serve import Engine, ServeConfig, materialize_served_params

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "granite_moe_1b_a400m",
                                  "zamba2_1_2b"])
def test_served_equals_fake_quant(arch):
    cfg = get_config(arch).reduced()
    params = api.init(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)}
    for bits in (8, 2):
        sp = materialize_served_params(params, cfg, bits)
        l_served, _ = api.forward(sp, batch, cfg, bits=None)
        l_fq, _ = api.forward(params, batch, cfg, bits=bits)
        np.testing.assert_allclose(np.asarray(l_served), np.asarray(l_fq),
                                   rtol=1e-3, atol=1e-3)


def test_served_mixnmatch_per_layer():
    cfg = get_config("qwen3_1_7b").reduced()  # 2 layers
    params = api.init(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)}
    assignment = [8, 2]
    sp = materialize_served_params(params, cfg, assignment)
    l_served, _ = api.forward(sp, batch, cfg, bits=None)
    l_fq, _ = api.forward(params, batch, cfg, bits=assignment)
    np.testing.assert_allclose(np.asarray(l_served), np.asarray(l_fq),
                               rtol=1e-3, atol=1e-3)


def test_engine_generation_deterministic():
    cfg = get_config("qwen3_1_7b").reduced()
    params = api.init(KEY, cfg)
    eng = Engine(params, cfg, ServeConfig(bits=4, max_len=48))
    prompts = jax.random.randint(KEY, (3, 8), 0, cfg.vocab_size, jnp.int32)
    g1 = eng.generate(prompts, 6)
    g2 = eng.generate(prompts, 6)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
    assert g1.shape == (3, 6)


def test_attn_scope_quantizes_attention_weights():
    cfg = get_config("qwen3_1_7b").reduced().replace(
        quant=QuantConfig(scope="ffn+attn"))
    params = api.init(KEY, cfg)
    sp = materialize_served_params(params, cfg, 2)
    wq_orig = params["layers"]["attn"]["wq"]["w"]
    wq_served = sp["layers"]["attn"]["wq"]["w"]
    assert not np.allclose(np.asarray(wq_orig), np.asarray(wq_served))
    # ffn-only scope leaves attention untouched
    cfg2 = get_config("qwen3_1_7b").reduced()
    sp2 = materialize_served_params(params, cfg2, 2)
    np.testing.assert_array_equal(np.asarray(sp2["layers"]["attn"]["wq"]["w"]),
                                  np.asarray(wq_orig))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_corpus_deterministic_and_host_disjoint():
    corpus = SyntheticCorpus(DataConfig(vocab_size=128, seed=3))
    b1 = corpus.batch(7, 4, 32, host_id=0)
    b2 = corpus.batch(7, 4, 32, host_id=0)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = corpus.batch(7, 4, 32, host_id=1)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    full = corpus.batch(0, 2, 16)
    np.testing.assert_array_equal(full["tokens"][:, 1:], full["labels"][:, :-1])


def test_host_sharded_generator():
    corpus = SyntheticCorpus(DataConfig(vocab_size=64))
    batches = list(host_sharded_batches(
        corpus, num_steps=3, global_batch=8, seq_len=16,
        host_id=1, num_hosts=2))
    assert len(batches) == 3
    assert batches[0]["tokens"].shape == (4, 16)


def test_markov_structure_is_learnable():
    """Bigram statistics are concentrated: the corpus is not iid noise."""
    corpus = SyntheticCorpus(DataConfig(vocab_size=64, branching=8))
    toks = corpus.batch(0, 16, 256)["tokens"]
    # successors of token 0 must lie in its 8-successor set
    succ = set(corpus.successors[0].tolist())
    following = toks[:, 1:][toks[:, :-1] == 0]
    if following.size:
        assert set(np.asarray(following).tolist()) <= succ


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


def _mesh(shape=(2, 4), names=("data", "model")):
    import os
    devs = np.array(jax.devices()[:1] * int(np.prod(shape))).reshape(shape)
    from jax.sharding import Mesh
    return Mesh(devs, names)


def test_resolve_spec_divisibility_fallback():
    # production-like model axis of 16: 40 experts don't divide -> the
    # experts dim falls through and the 512 expert-hidden dim takes it
    mesh = _mesh((2, 16), ("data", "model"))
    spec = shard.resolve_spec(("experts", "embed", "expert_mlp"),
                              (40, 1536, 512), mesh)
    assert spec == jax.sharding.PartitionSpec(None, "data", "model")
    # 32 experts divisible by model=4 -> experts take model
    mesh4 = _mesh()
    spec2 = shard.resolve_spec(("experts", "embed", "expert_mlp"),
                               (32, 1024, 512), mesh4)
    assert spec2[0] == "model"


def test_resolve_spec_no_axis_reuse():
    mesh = _mesh()
    spec = shard.resolve_spec(("mlp", "inner"), (512, 512), mesh)
    used = [s for s in spec if s is not None]
    assert len(set(used)) == len(used)


def test_resolve_spec_batch_multi_axis():
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    spec = shard.resolve_spec(("batch", "seq"), (8, 128), mesh)
    assert spec[0] == ("pod", "data")
    # batch=1 cannot shard -> replicated
    spec1 = shard.resolve_spec(("batch", "seq"), (1, 128), mesh)
    assert len(spec1) == 0 or spec1[0] is None


def test_tree_shardings_structure_match():
    mesh = _mesh()
    cfg = get_config("qwen3_1_7b").reduced()
    pspec = jax.eval_shape(lambda k: api.init(k, cfg), KEY)
    sh = shard.tree_shardings(api.axes(cfg), pspec, mesh)
    assert jax.tree.structure(sh) == jax.tree.structure(
        jax.tree.map(lambda x: 0, pspec))
