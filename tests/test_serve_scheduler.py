"""Continuous-batching serving subsystem tests.

Covers the acceptance surface of the scheduler: single-batch token
identity with the legacy engine loop, admit/evict under a scripted
arrival trace, KV-slot reuse after eviction, elastic-precision
downgrade/recovery, page-pool accounting + defrag, the packed-path
wiring, and the ragged-M kernel guard.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve import (ContinuousBatchingScheduler, ElasticPrecisionRouter,
                         Engine, PagePool, Request, ServeConfig, TierCache,
                         default_tiers)
from repro.serve import engine as engine_mod

KEY = jax.random.PRNGKey(0)


class FakeClock:
    """Manually advanced time source for deterministic scheduling tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@pytest.fixture(scope="module")
def served():
    cfg = get_config("qwen3_1_7b").reduced()
    params = api.init(KEY, cfg)
    eng = Engine(params, cfg, ServeConfig(bits=4, max_len=32, num_slots=2,
                                          page_size=8))
    return params, cfg, eng


def _prompts(cfg, B, S, seed=1):
    return jax.random.randint(jax.random.fold_in(KEY, seed), (B, S), 0,
                              cfg.vocab_size)


# ---------------------------------------------------------------------------
# token identity with the legacy path
# ---------------------------------------------------------------------------


def test_scheduler_single_batch_token_identical(served):
    _, cfg, eng = served
    prompts = _prompts(cfg, 3, 16)
    legacy = np.asarray(eng.generate_legacy(prompts, 8))
    sched = np.asarray(eng.generate(prompts, 8))   # facade -> scheduler
    np.testing.assert_array_equal(legacy, sched)


def test_prefill_bucket_padding_is_exact(served):
    """Prompt lengths off the bucket grid (12 -> padded 16) still match."""
    _, cfg, eng = served
    prompts = _prompts(cfg, 2, 12, seed=7)
    legacy = np.asarray(eng.generate_legacy(prompts, 6))
    sched = np.asarray(eng.generate(prompts, 6))
    np.testing.assert_array_equal(legacy, sched)


# ---------------------------------------------------------------------------
# admit / evict / slot reuse
# ---------------------------------------------------------------------------


def test_admit_evict_and_slot_reuse(served):
    params, cfg, eng = served
    clock = FakeClock()
    sched = eng.scheduler(num_slots=2, max_len=32, clock=clock)
    rng = np.random.default_rng(0)
    prompts = {f"r{i}": rng.integers(0, cfg.vocab_size, size=8)
               for i in range(5)}
    mnt = {"r0": 3, "r1": 6, "r2": 3, "r3": 3, "r4": 3}
    for uid, p in prompts.items():
        sched.submit(Request(uid=uid, prompt=p, max_new_tokens=mnt[uid]))
    assert len(sched.queue) == 5

    clock.t = 1.0
    sched.step()
    # two slots -> r0, r1 admitted; the rest wait
    assert sorted(a.req.uid for a in sched.active.values()) == ["r0", "r1"]
    assert len(sched.queue) == 3

    clock.t = 2.0
    sched.step()  # r0 (max_new=2) finished last step or this one; r2 reuses
    while "r0" not in sched.results:
        clock.t += 1.0
        sched.step()
    clock.t += 1.0
    sched.step()           # admission runs at the start of the next step
    freed_uids = [a.req.uid for a in sched.active.values()]
    assert "r2" in freed_uids or "r2" in sched.results  # admitted after evict
    slots_of_r2 = [s for s, a in sched.active.items() if a.req.uid == "r2"]
    if slots_of_r2:
        assert slots_of_r2[0] == 0      # lowest freed slot is reused

    while sched.queue or sched.active:
        clock.t += 1.0
        sched.step()
    assert sorted(sched.results) == sorted(prompts)
    assert sched.pool.active_slots == [] and sched.pool.used_pages == 0
    for uid in prompts:
        assert len(sched.results[uid]) == mnt[uid]
    # metrics recorded the full lifecycle under the fake clock
    s = sched.metrics.summary()
    assert s["requests_completed"] == 5
    assert s["mean_ttft_s"] >= 0.0 and s["max_queue_depth"] >= 3
    # TTFT percentiles interpolate the per-request distribution
    assert 0.0 <= s["p50_ttft_s"] <= s["p95_ttft_s"] <= s["max_ttft_s"]
    # decoded-token counts exclude prefill first-tokens: 5 requests
    # each generated max_new tokens, the first from prefill
    total_new = sum(mnt.values())
    assert sum(s["tier_decoded_tokens"].values()) == total_new - 5
    assert sum(s["tier_tokens"].values()) == total_new


def test_reused_slot_is_clean(served):
    """Tokens of a request admitted into a freed slot match an isolated
    run -- no KV leakage from the slot's previous occupant."""
    _, cfg, eng = served
    sched = eng.scheduler(num_slots=1, max_len=32)
    rng = np.random.default_rng(3)
    p0 = rng.integers(0, cfg.vocab_size, size=16)
    p1 = rng.integers(0, cfg.vocab_size, size=16)
    sched.submit(Request(uid="a", prompt=p0, max_new_tokens=5))
    sched.submit(Request(uid="b", prompt=p1, max_new_tokens=5))
    res = sched.run_until_idle()
    iso = np.asarray(eng.generate_legacy(jnp.asarray(p1[None]), 5))[0]
    np.testing.assert_array_equal(res["b"], iso)


def test_defrag_compacts_and_preserves_outputs(served):
    _, cfg, eng = served
    sched = eng.scheduler(num_slots=3, max_len=32)
    rng = np.random.default_rng(5)
    ps = [rng.integers(0, cfg.vocab_size, size=8) for _ in range(3)]
    sched.submit(Request(uid=0, prompt=ps[0], max_new_tokens=2))
    sched.submit(Request(uid=1, prompt=ps[1], max_new_tokens=10))
    sched.submit(Request(uid=2, prompt=ps[2], max_new_tokens=10))
    sched.step()
    while 0 not in sched.results:
        sched.step()
    assert sched.pool.active_slots == [1, 2]     # hole at slot 0
    moves = sched.defrag()
    assert moves == {1: 0, 2: 1}
    assert sched.pool.active_slots == [0, 1]
    res = sched.run_until_idle()
    for uid in (1, 2):
        iso = np.asarray(eng.generate_legacy(
            jnp.asarray(ps[uid][None]), 10))[0]
        np.testing.assert_array_equal(res[uid], iso)


def test_defrag_preserves_kv_contents_and_positions(served):
    """Regression: the defrag permutation moves each live slot's KV
    rows and position counter VERBATIM -- byte-identical cache contents
    at the new slot index, not just equal final outputs."""
    from repro.serve import kv_cache
    _, cfg, eng = served
    sched = eng.scheduler(num_slots=3, max_len=32)
    rng = np.random.default_rng(6)
    for uid, mnt in ((0, 2), (1, 12), (2, 12)):
        sched.submit(Request(uid=uid, prompt=rng.integers(
            0, cfg.vocab_size, size=8), max_new_tokens=mnt))
    sched.step()
    while 0 not in sched.results:
        sched.step()
    assert sched.pool.active_slots == [1, 2]       # hole at slot 0
    before = jax.tree_util.tree_leaves(
        jax.tree.map(np.asarray, sched.state))
    pos_before = sched.pos.copy()
    gen_before = {s: list(a.generated) for s, a in sched.active.items()}
    moves = sched.defrag()
    assert moves == {1: 0, 2: 1}
    assert (sched.pos[[0, 1]] == pos_before[[1, 2]]).all()
    after = jax.tree_util.tree_leaves(
        jax.tree.map(np.asarray, sched.state))
    for old, new, b in zip(before, after, kv_cache.state_batch_axes(cfg)):
        old = np.moveaxis(old, b, 0)
        new = np.moveaxis(new, b, 0)
        np.testing.assert_array_equal(new[0], old[1])
        np.testing.assert_array_equal(new[1], old[2])
    # request bookkeeping followed the permutation
    assert {s: a.generated for s, a in sched.active.items()} == {
        0: gen_before[1], 1: gen_before[2]}
    # and the run completes identically from the compacted state
    res = sched.run_until_idle()
    assert len(res[1]) == 12 and len(res[2]) == 12


# ---------------------------------------------------------------------------
# elastic precision router
# ---------------------------------------------------------------------------


def test_router_downgrades_then_recovers():
    tiers = default_tiers(2)
    assert [t.name for t in tiers] == [
        "int8", "int4", tiers[2].name, "int2+ep", "int2"]
    r = ElasticPrecisionRouter(tiers, thresholds=(2, 6, 12, 20), cooldown=2)
    assert r.tier.name == "int8"
    assert r.observe(30.0).name == "int2"          # overload: immediate drop
    assert r.observe(30.0).name == "int2"
    # calm load: recover one tier per `cooldown` observations, stepping
    # back UP through the extra-precision rung before Mix'n'Match
    names = [r.observe(0.0).name for _ in range(8)]
    assert names == ["int2", "int2+ep", "int2+ep", tiers[2].name,
                     tiers[2].name, "int4", "int4", "int8"]
    # hysteresis: a single calm step does not upgrade
    r2 = ElasticPrecisionRouter(tiers, thresholds=(2, 6, 12, 20), cooldown=3)
    r2.observe(8.0)
    assert r2.tier.name == tiers[2].name
    r2.observe(1.0)
    r2.observe(5.0)                                # load back over tier-1 thr
    assert r2.tier.name == tiers[2].name


def test_elastic_scheduler_downgrades_under_load(served):
    params, cfg, _ = served
    eng = Engine(params, cfg, ServeConfig(bits=8, max_len=32, num_slots=2,
                                          page_size=8))
    sched = eng.scheduler(elastic=True, thresholds=(1, 3, 6, 9), cooldown=2)
    rng = np.random.default_rng(0)
    for i in range(10):
        sched.submit(Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 8),
                             max_new_tokens=4))
    sched.run_until_idle()
    occ = sched.metrics.summary()["tier_occupancy"]
    assert "int2" in occ                     # deep queue hit the lowest tier
    assert len(sched.results) == 10
    assert sched.tier.name != "int2"         # drain started the recovery
    # after the drain the router has begun recovering toward int8
    for _ in range(8):
        sched.router.observe(0.0)
    assert sched.router.tier.name == "int8"
    # tier params are cached: switching back is a dict lookup
    assert set(sched.tier_cache.materialized) >= {"int8", "int2"}


def test_tier_cache_materializes_once(served):
    params, cfg, _ = served
    cache = TierCache(params, cfg)
    t = default_tiers(cfg.num_layers)[1]
    a = cache.get(t)
    assert cache.get(t) is a


# ---------------------------------------------------------------------------
# page pool
# ---------------------------------------------------------------------------


def test_page_pool_accounting():
    pool = PagePool(num_slots=3, page_size=8, pages_per_slot=4,
                    total_pages=8)                 # overcommitted budget
    assert pool.slot_capacity == 32
    s0 = pool.allocate("a", 20)                    # 3 pages
    s1 = pool.allocate("b", 33)                    # > pages_per_slot
    assert s0 == 0 and s1 is None
    s2 = pool.allocate("c", 40)
    assert s2 is None                              # still too big
    s3 = pool.allocate("d", 30)                    # 4 pages -> 7/8 used
    assert s3 == 1 and pool.free_pages == 1
    assert pool.allocate("e", 9) is None           # 2 pages > 1 free
    assert pool.allocate("f", 8) == 2              # exactly 1 page
    assert pool.free_pages == 0
    pool.free(0)
    assert pool.free_pages == 3 and pool.free_slots == [0]
    assert pool.allocate("g", 24) == 0             # slot + pages reused


def test_page_pool_defrag():
    pool = PagePool(num_slots=4, page_size=8, pages_per_slot=2)
    for uid in "abcd":
        pool.allocate(uid, 8)
    pool.free(0)
    pool.free(2)
    perm, moves = pool.defrag()
    assert perm[:2] == [1, 3] and sorted(perm) == [0, 1, 2, 3]
    assert moves == {1: 0, 3: 1}
    assert pool.active_slots == [0, 1]
    assert pool.owner(0) == "b" and pool.owner(1) == "d"


# ---------------------------------------------------------------------------
# packed-path wiring (ServeConfig.use_packed)
# ---------------------------------------------------------------------------


def test_use_packed_falls_back_off_tpu(served, monkeypatch):
    params, cfg, _ = served
    monkeypatch.setattr(engine_mod, "_packed_backend_ok", lambda: False)
    with pytest.warns(UserWarning, match="no TPU backend"):
        eng = Engine(params, cfg, ServeConfig(bits=4, max_len=24,
                                              use_packed=True))
    assert not eng.packed
    assert eng.cfg.quant.packed_bits == 0          # dequantized path served


def test_use_packed_routes_through_packed_planes(served, monkeypatch):
    params, cfg, _ = served
    monkeypatch.setattr(engine_mod, "_packed_backend_ok", lambda: True)
    with warnings.catch_warnings():
        warnings.simplefilter("error")             # no fallback warning
        eng = Engine(params, cfg, ServeConfig(bits=4, max_len=24,
                                              use_packed=True))
    assert eng.packed and eng.cfg.quant.packed_bits == 4
    # scoped dense projections became packed planes
    from repro.core.packing import PackedPlane
    w = eng.params["layers"]["ffn"]["up"]["w"]
    assert isinstance(w, PackedPlane) and w.bits == 4
    # generate/score run through the packed qlinear path and agree with
    # the dequantized engine
    ref = Engine(params, cfg, ServeConfig(bits=4, max_len=24))
    prompts = _prompts(cfg, 2, 8, seed=9)
    out = np.asarray(eng.generate(prompts, 4))
    assert out.shape == (2, 4)
    labels = _prompts(cfg, 2, 8, seed=10)
    assert abs(eng.score(prompts, labels) - ref.score(prompts, labels)) < 1e-2


def test_use_packed_serves_mixnmatch_bits_per_layer(served, monkeypatch):
    """A per-layer bits vector no longer forces the dequantized detour:
    the engine serves per-layer packed planes (layers unstacked)."""
    from repro.core.packing import PackedPlane
    params, cfg, _ = served
    monkeypatch.setattr(engine_mod, "_packed_backend_ok", lambda: True)
    with warnings.catch_warnings():
        warnings.simplefilter("error")             # no fallback warning
        eng = Engine(params, cfg, ServeConfig(bits=[8, 4], max_len=24,
                                              use_packed=True))
    assert eng.packed and eng._packed_key == (8, 4)
    assert isinstance(eng.params["layers"], list)
    assert eng.params["layers"][1]["ffn"]["up"]["w"].bits == 4
    ref = Engine(params, cfg, ServeConfig(bits=[8, 4], max_len=24))
    prompts = _prompts(cfg, 2, 8, seed=11)
    np.testing.assert_array_equal(np.asarray(eng.generate(prompts, 4)),
                                  np.asarray(ref.generate(prompts, 4)))
    assert isinstance(eng.params["layers"][0]["ffn"]["down"]["w"], PackedPlane)


def test_use_packed_supports_extra_precision(served, monkeypatch):
    """PR 4: ServeConfig(use_packed=True, extra_precision=True) serves
    packed planes carrying the overflow bitmap -- no dequant fallback."""
    from repro.core.packing import PackedPlane
    params, cfg, _ = served
    monkeypatch.setattr(engine_mod, "_packed_backend_ok", lambda: True)
    eng = Engine(params, cfg, ServeConfig(bits=4, max_len=24,
                                          use_packed=True,
                                          extra_precision=True))
    assert eng.packed
    assert eng._packed_key == (4, "ep")
    plane = eng.params["layers"]["ffn"]["up"]["w"]
    assert isinstance(plane, PackedPlane) and plane.extra_precision
    assert plane.overflow is not None


# ---------------------------------------------------------------------------
# ragged-M kernel guard
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M", [1, 9, 130])
def test_quant_matmul_ragged_m(M):
    from repro.core import packing, quant
    from repro.kernels.quant_matmul import quant_matmul_pallas
    K, N, bits = 128, 128, 4
    w = jax.random.normal(jax.random.fold_in(KEY, M), (K, N))
    q, alpha, z = quant.quantize(np.asarray(w, np.float32), 8, axis=0)
    codes = quant.sliced_codes(q, 8, bits)
    words = packing.pack_codes(codes, bits, axis=0)
    scale = jnp.asarray(2 ** (8 - bits), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, M + 1), (M, K))
    y = quant_matmul_pallas(x, words, alpha * scale, alpha * z, bits=bits,
                            block_m=128, block_n=128, block_k=128,
                            interpret=True)
    w_hat = alpha * scale * codes - alpha * z
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w_hat),
                               rtol=1e-4, atol=1e-4)
    # K/N raggedness is still rejected
    with pytest.raises(AssertionError):
        quant_matmul_pallas(x, words[:, :100], alpha[:, :100] * scale,
                            (alpha * z)[:, :100], bits=bits, interpret=True)


# ---------------------------------------------------------------------------
# score jit-cache
# ---------------------------------------------------------------------------


def test_score_is_jit_cached(served):
    _, cfg, eng = served
    toks = _prompts(cfg, 2, 8, seed=20)
    labels = _prompts(cfg, 2, 8, seed=21)
    a = eng.score(toks, labels)
    b = eng.score(toks, labels)
    assert a == b
    # same-shape second call hits the jit cache (no retrace)
    assert eng._score_logits._cache_size() == 1
