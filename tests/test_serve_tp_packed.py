"""TP-sharded packed elastic serving on a forced 8-device host mesh.

Run via `make test-shard` (or the CI `shard` job), which sets
XLA_FLAGS=--xla_force_host_platform_device_count=8 before jax is
imported; under the plain 1-device tier-1 run this module skips.

What is pinned down here:

  * sharded packed decode is BIT-EXACT (token-identical greedy
    continuations) vs the single-device oracle at every rung of the
    ladder -- int8, int4, packed Mix'n'Match, int2+ep (overflow
    bitmap), int2 -- at model_parallel 2 and 4, for dense and MoE;
  * a mid-flight tier downgrade on the mesh keeps the one-compile-
    per-representation-key guarantee (no recompile on revisit);
  * every tier's per-device plane bytes are exactly
    packed_nbytes / model_parallel (the HBM footprint the TP shard
    actually divides), reported through TierEntry and ServeMetrics.
"""

import numpy as np
import pytest

import jax

if len(jax.devices()) < 8:          # pragma: no cover - env-dependent gate
    pytest.skip(
        "sharded serving tests need 8 host devices: run `make test-shard` "
        "or set XLA_FLAGS=--xla_force_host_platform_device_count=8 before "
        "jax is imported", allow_module_level=True)

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.runtime.sharding import mesh_axis_sizes
from repro.serve import Engine, Request, ServeConfig, TierCache, default_tiers

KEY = jax.random.PRNGKey(0)
N_RUNGS = 5


def _model(arch):
    cfg = get_config(arch).reduced()
    params = api.init(KEY, cfg)
    return cfg, params


@pytest.fixture(scope="module")
def dense():
    return _model("qwen3_1_7b")


@pytest.fixture(scope="module")
def moe():
    return _model("granite_moe_1b_a400m")


def _packed_sched(cfg, params, mesh):
    eng = Engine(params, cfg, ServeConfig(bits=8, max_len=32, num_slots=4,
                                          page_size=8), mesh=mesh)
    return eng.scheduler(elastic=True, packed=True)


def _pin(sched, index):
    """Hold the router at `index` for a whole replay (bench recipe)."""
    sched.router.thresholds = (float("inf"),) * (len(sched.router.tiers) - 1)
    sched.router.cooldown = 10**9
    sched.router.index = index
    sched._set_tier(sched.router.tier)


def _pinned_run(sched, cfg, index, gen_tokens=5):
    sched.reset()
    _pin(sched, index)
    rng = np.random.default_rng(5)
    for i in range(2):
        sched.submit(Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 8),
                             max_new_tokens=gen_tokens))
    return sched.run_until_idle()


# single-device oracle continuations, one per (fixture id, rung), shared
# across the mp=2 and mp=4 parametrizations
_ORACLE: dict = {}


def _oracle(name, cfg, params, index):
    key = (name, index)
    if key not in _ORACLE:
        _ORACLE[key] = _pinned_run(_packed_sched(cfg, params, None), cfg, index)
    return _ORACLE[key]


# ---------------------------------------------------------------------------
# bit-exact sharded decode at every ladder rung
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mp", [2, 4])
def test_dense_sharded_packed_ladder_bit_exact(dense, mp):
    cfg, params = dense
    sched = _packed_sched(cfg, params, make_host_mesh(mp))
    for index in range(N_RUNGS):
        got = _pinned_run(sched, cfg, index)
        want = _oracle("dense", cfg, params, index)
        assert set(got) == set(want)
        for uid in want:
            np.testing.assert_array_equal(
                got[uid], want[uid],
                err_msg=f"mp={mp} rung {sched.router.tiers[index].name}")


@pytest.mark.parametrize("mp", [2, 4])
def test_moe_sharded_packed_decode_bit_exact(moe, mp):
    """MoE expert stacks shard over 'model' (expert parallelism) and
    still decode token-identically, incl. the int2+ep overflow rung."""
    cfg, params = moe
    sched = _packed_sched(cfg, params, make_host_mesh(mp))
    for index in (0, 3, 4):            # int8, int2+ep, int2
        got = _pinned_run(sched, cfg, index)
        want = _oracle("moe", cfg, params, index)
        for uid in want:
            np.testing.assert_array_equal(
                got[uid], want[uid],
                err_msg=f"mp={mp} rung {sched.router.tiers[index].name}")


def test_fixed_tier_generate_on_mesh_matches_single_device(dense):
    """The non-elastic path: Engine.generate routes through a scheduler
    whose fixed-tier params/state are mesh-placed."""
    cfg, params = dense
    prompts = jax.random.randint(KEY, (3, 8), 0, cfg.vocab_size)
    out_tp = Engine(params, cfg, ServeConfig(bits=4, max_len=32),
                    mesh=make_host_mesh(2)).generate(prompts, 5)
    out_1d = Engine(params, cfg,
                    ServeConfig(bits=4, max_len=32)).generate(prompts, 5)
    np.testing.assert_array_equal(np.asarray(out_tp), np.asarray(out_1d))


def test_paged_fp_kv_on_mesh_token_identical(dense):
    """Paged fp-KV serving on an mp=2 mesh: the global page store shards
    heads-over-'model' (page dims replicated, host page table broadcast)
    and stays token-identical to the single-device DENSE slot path --
    the paged exactness gate composed with TP sharding."""
    cfg, params = dense
    prompts = jax.random.randint(KEY, (3, 8), 0, cfg.vocab_size)
    ref = Engine(params, cfg,
                 ServeConfig(bits=4, max_len=32)).generate(prompts, 5)
    paged_tp = Engine(params, cfg,
                      ServeConfig(bits=4, max_len=32, kv_bits="fp"),
                      mesh=make_host_mesh(2)).generate(prompts, 5)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(paged_tp))


def test_paged_quant_kv_on_mesh_matches_single_device(dense):
    """int8 KV pages attended at the int4 slice on an mp=2 mesh emit the
    same tokens as the identical single-device paged run (the quantized
    gather/dequant graph is shard-invariant)."""
    cfg, params = dense
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    one = Engine(params, cfg,
                 ServeConfig(bits=4, max_len=32,
                             kv_bits=4)).generate(prompts, 5)
    tp = Engine(params, cfg,
                ServeConfig(bits=4, max_len=32, kv_bits=4),
                mesh=make_host_mesh(2)).generate(prompts, 5)
    np.testing.assert_array_equal(np.asarray(one), np.asarray(tp))


# ---------------------------------------------------------------------------
# mid-flight tier switching on the mesh: one compile per representation
# ---------------------------------------------------------------------------


def test_midflight_downgrade_on_mesh_no_recompile(dense):
    cfg, params = dense
    sched = _packed_sched(cfg, params, make_host_mesh(2))
    oracle = _packed_sched(cfg, params, None)
    switches = [0, 3, 4, 3, 0, 3]      # int8 -> int2+ep -> int2 -> revisits
    results = {}
    for s in (sched, oracle):
        s.router.thresholds = (float("inf"),) * (len(s.router.tiers) - 1)
        s.router.cooldown = 10**9
        rng = np.random.default_rng(7)
        for i in range(2):
            s.submit(Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 8),
                             max_new_tokens=len(switches) + 1))
        for index in switches:
            s.router.index = index
            s.step()
        s.router.index = 0
        results[s] = s.run_until_idle()
    for uid in results[oracle]:
        np.testing.assert_array_equal(results[sched][uid],
                                      results[oracle][uid])
    # the mesh does not change representation keying: one closure per
    # packed key, one decode compile per closure even after revisits
    assert {8, 2, (2, "ep")} <= set(sched._fns)
    for key in (8, 2, (2, "ep")):
        assert sched._fns[key]["decode"]._cache_size() == 1


# ---------------------------------------------------------------------------
# per-device plane bytes == total / model_parallel, every rung
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mp", [2, 4])
@pytest.mark.parametrize("arch", ["qwen3_1_7b", "granite_moe_1b_a400m"])
def test_per_device_plane_bytes_divide_by_model_parallel(arch, mp):
    """Materialization-only (no decode): every ladder rung's sharded
    planes put exactly packed_nbytes / mp on each device. 4 layers so
    the Mix'n'Match rung (3.5 eff bits) keeps the per-device staircase
    strictly decreasing, matching the BENCH packed_ab_tp section."""
    cfg = get_config(arch).reduced().replace(num_layers=4)
    params = api.init(KEY, cfg)
    cache = TierCache(params, cfg, packed=True, mesh=make_host_mesh(mp))
    per_dev = []
    for tier in default_tiers(cfg.num_layers):
        entry = cache.get(tier)
        assert entry.per_device_plane_nbytes * mp == entry.packed_nbytes, \
            (tier.name, mp, entry.per_device_plane_nbytes, entry.packed_nbytes)
        per_dev.append(entry.per_device_plane_nbytes)
    assert all(a > b for a, b in zip(per_dev, per_dev[1:])), per_dev


def test_scheduler_metrics_report_per_device_bytes(dense):
    cfg, params = dense
    mp = 2
    sched = _packed_sched(cfg, params, make_host_mesh(mp))
    _pinned_run(sched, cfg, 1)         # serve the int4 rung
    rec = sched.metrics.summary()["tier_weight_bytes"]["int4"]
    assert rec["per_device_plane_nbytes"] * mp == rec["packed_nbytes"] > 0


def test_make_host_mesh_names_the_cpu_escape_hatch():
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        make_host_mesh(3)              # 8 % 3 != 0
    mesh = make_host_mesh(1)           # degenerate model axis is valid
    assert mesh_axis_sizes(mesh) == {"data": 8, "model": 1}
