"""Matryoshka self-speculative decoding (serve/specdecode.py).

The acceptance surface of the draft/verify subsystem:

  * the aliased draft view (`core.packing.sliced_view`) is BIT-EXACT vs
    a materialized r-bit plane on every matmul path (twin + interpret
    kernel, K-/N-packed, plain + extra-precision slice) while sharing
    the parent plane's words buffer;
  * greedy spec decode is TOKEN-IDENTICAL to plain verify-tier decode
    (dense + MoE, dequant + forced-packed engines, several (draft,
    verify) pairs, and -- under the shard job's forced 8-device mesh --
    model-parallel 2), with zero additional plane bytes on the packed
    path;
  * `kv_cache.rollback_slots` clears exactly the rows past each slot's
    accepted prefix (unit + after a real partial rejection);
  * acceptance bookkeeping: the `accept_lengths` NumPy oracle and the
    in-graph acceptance agree, and ServeMetrics invariants hold
    (emitted = accepted + rounds, verify steps < emitted tokens);
  * one compiled (draft, verify) closure pair per `("spec", draft_key,
    verify_key)` -- no recompile across rounds or resets.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import packing
from repro.kernels import ops
from repro.models import api
from repro.runtime.compile_guard import assert_no_recompiles
from repro.serve import (Engine, Request, ServeConfig, SpecDecodeConfig,
                         accept_lengths, extra_plane_nbytes)
from repro.serve import engine as engine_mod
from repro.serve import kv_cache

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("qwen3_1_7b").reduced()
    params = api.init(KEY, cfg)
    return cfg, params


@pytest.fixture(scope="module")
def moe():
    cfg = get_config("granite_moe_1b_a400m").reduced()
    params = api.init(KEY, cfg)
    return cfg, params


def _engine(cfg, params, mesh=None, packed=False, monkeypatch=None):
    if packed:
        monkeypatch.setattr(engine_mod, "_packed_backend_ok", lambda: True)
    return Engine(params, cfg, ServeConfig(bits=8, max_len=48, num_slots=4,
                                           page_size=8, use_packed=packed),
                  mesh=mesh)


# ---------------------------------------------------------------------------
# the aliased draft view: bit-exact and byte-free
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pack_axis", [-2, -1])
@pytest.mark.parametrize("bits,ep", [(4, False), (2, False), (2, True)])
def test_sliced_view_matches_materialized_plane(bits, ep, pack_axis):
    """plane_matmul through the zero-copy slice view == through the
    materialized r-bit plane, on the jnp twin and (K-packed) the
    interpret-mode kernel."""
    w = jax.random.normal(jax.random.fold_in(KEY, bits + pack_axis), (64, 48))
    x = jax.random.normal(jax.random.fold_in(KEY, 7), (4, 64))
    pl = packing.PackedLinear.from_weights(w, pack_axis=pack_axis)
    parent = pl.materialize_plane(8)
    view = packing.sliced_view(parent, bits, extra_precision=ep)
    oracle = pl.materialize_plane(bits, extra_precision=ep)
    # the view aliases the parent's bytes; the oracle stores its own
    assert view.words is parent.words and view.beta is parent.beta
    assert view.overflow is None and view.slice_bits == bits
    np.testing.assert_array_equal(
        np.asarray(ops.plane_matmul(x, view)),
        np.asarray(ops.plane_matmul(x, oracle)))
    if pack_axis == -2:
        np.testing.assert_array_equal(
            np.asarray(ops.plane_matmul(x, view, use_kernel=True,
                                        interpret=True)),
            np.asarray(ops.plane_matmul(x, oracle, use_kernel=True,
                                        interpret=True)))


def test_sliced_view_expert_stack_matches_materialized():
    """The aliased slice also serves (E, k, n) expert stacks."""
    E, K, N = 4, 32, 24
    w = jax.random.normal(jax.random.fold_in(KEY, 11), (E, K, N))
    x = jax.random.normal(jax.random.fold_in(KEY, 12), (E, 3, K))
    pl = packing.PackedLinear.from_weights(w)
    view = packing.sliced_view(pl.materialize_plane(8), 2)
    oracle = pl.materialize_plane(2)
    np.testing.assert_array_equal(np.asarray(ops.plane_matmul(x, view)),
                                  np.asarray(ops.plane_matmul(x, oracle)))


def test_sliced_view_rejects_bad_parents():
    w = jax.random.normal(KEY, (32, 16))
    pl = packing.PackedLinear.from_weights(w)
    parent = pl.materialize_plane(8)
    with pytest.raises(ValueError, match="not in"):
        packing.sliced_view(parent, 9)
    with pytest.raises(ValueError, match="re-slice"):
        packing.sliced_view(packing.sliced_view(parent, 4), 2)
    with pytest.raises(ValueError, match="non-ep"):
        packing.sliced_view(pl.materialize_plane(4, extra_precision=True), 2)
    # a full-width non-ep slice is the parent itself
    assert packing.sliced_view(parent, 8) is parent


def test_draft_params_alias_packed_tier(dense, monkeypatch):
    """Zero additional plane bytes: every draft plane of a packed tier
    shares its words buffer with the resident tier."""
    cfg, params = dense
    eng = _engine(cfg, params, packed=True, monkeypatch=monkeypatch)
    sched = eng.scheduler(num_slots=2, max_len=32,
                          spec_decode=SpecDecodeConfig(draft_bits=2))
    draft, _ = sched._spec_draft()
    assert extra_plane_nbytes(draft, sched.params) == 0
    # ... while the dequant fallback materializes real draft bytes
    eng_d = _engine(cfg, params)
    sched_d = eng_d.scheduler(num_slots=2, max_len=32,
                              spec_decode=SpecDecodeConfig(draft_bits=2))
    draft_d, _ = sched_d._spec_draft()
    assert extra_plane_nbytes(draft_d, sched_d.params) > 0


# ---------------------------------------------------------------------------
# token-exactness vs the plain verify-tier oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("draft_bits,draft_ep,k", [(2, False, 4),
                                                   (4, False, 3),
                                                   (2, True, 2)])
def test_spec_decode_token_exact_dense(dense, draft_bits, draft_ep, k):
    cfg, params = dense
    eng = _engine(cfg, params)
    prompts = jax.random.randint(jax.random.fold_in(KEY, k), (3, 6), 0,
                                 cfg.vocab_size)
    plain = np.asarray(eng.generate(prompts, 10))
    spec = np.asarray(eng.generate(
        prompts, 10, spec_decode=SpecDecodeConfig(
            draft_bits=draft_bits, draft_extra_precision=draft_ep,
            draft_len=k)))
    np.testing.assert_array_equal(plain, spec)


def test_spec_decode_token_exact_packed(dense, monkeypatch):
    """Packed path: the draft runs through the aliased slice view."""
    cfg, params = dense
    eng = _engine(cfg, params, packed=True, monkeypatch=monkeypatch)
    prompts = jax.random.randint(jax.random.fold_in(KEY, 21), (3, 6), 0,
                                 cfg.vocab_size)
    plain = np.asarray(eng.generate(prompts, 10))
    for sd in (SpecDecodeConfig(draft_bits=2, draft_len=4),
               SpecDecodeConfig(draft_bits=4, draft_len=3)):
        np.testing.assert_array_equal(
            plain, np.asarray(eng.generate(prompts, 10, spec_decode=sd)))


def test_spec_decode_token_exact_moe(moe):
    """MoE verify: the k+1-row block never drops tokens (capacity floor
    in verify_step_slots), so spec decode stays token-exact."""
    cfg, params = moe
    eng = _engine(cfg, params)
    prompts = jax.random.randint(jax.random.fold_in(KEY, 31), (3, 6), 0,
                                 cfg.vocab_size)
    plain = np.asarray(eng.generate(prompts, 8))
    spec = np.asarray(eng.generate(
        prompts, 8, spec_decode=SpecDecodeConfig(draft_bits=2, draft_len=3)))
    np.testing.assert_array_equal(plain, spec)


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs the forced 8-device host mesh (run via "
                           "`make test-shard` / the CI shard job)")
def test_spec_decode_token_exact_on_mesh(dense, monkeypatch):
    """Model-parallel 2: the draft closure reuses the PR-5 mesh
    shardings (aliased planes are already placed) and stays
    token-identical to the plain sharded decode."""
    from repro.launch.mesh import make_host_mesh
    cfg, params = dense
    eng = _engine(cfg, params, mesh=make_host_mesh(2), packed=True,
                  monkeypatch=monkeypatch)
    prompts = jax.random.randint(jax.random.fold_in(KEY, 41), (4, 6), 0,
                                 cfg.vocab_size)
    plain = np.asarray(eng.generate(prompts, 8))
    spec = np.asarray(eng.generate(
        prompts, 8, spec_decode=SpecDecodeConfig(draft_bits=2, draft_len=3)))
    np.testing.assert_array_equal(plain, spec)
    sched = next(iter(eng._schedulers.values()))
    draft, _ = sched._spec_draft()
    assert extra_plane_nbytes(draft, sched.params) == 0


def test_spec_decode_eos_truncation(dense):
    """A draft block crossing EOS/max_new emits only up to the stop."""
    cfg, params = dense
    eng = _engine(cfg, params)
    prompts = jax.random.randint(jax.random.fold_in(KEY, 51), (2, 6), 0,
                                 cfg.vocab_size)
    plain = np.asarray(eng.generate(prompts, 7))    # 7 % (k+1) != 0
    spec = np.asarray(eng.generate(
        prompts, 7, spec_decode=SpecDecodeConfig(draft_bits=4, draft_len=4)))
    np.testing.assert_array_equal(plain, spec)


# ---------------------------------------------------------------------------
# KV rollback
# ---------------------------------------------------------------------------


def test_rollback_slots_unit(dense):
    """Rows >= pos[slot] are zeroed, rows < pos[slot] untouched."""
    cfg, _ = dense
    state = api.init_state(cfg, 3, 10)
    leaves, treedef = jax.tree_util.tree_flatten(state)
    rng = np.random.default_rng(0)
    filled = jax.tree_util.tree_unflatten(treedef, [
        jnp.asarray(rng.normal(size=leaf.shape), leaf.dtype)
        for leaf in leaves])
    pos = np.asarray([0, 4, 10], np.int32)
    rolled = kv_cache.rollback_slots(
        filled, pos, kv_cache.state_batch_axes(cfg),
        kv_cache.state_seq_axes(cfg))
    axes = jax.tree_util.tree_flatten(
        api.state_axes(cfg), is_leaf=lambda x: isinstance(x, tuple))[0]
    for leaf, old, ax in zip(jax.tree_util.tree_leaves(rolled),
                             jax.tree_util.tree_leaves(filled), axes):
        if "kv_seq" not in ax:
            np.testing.assert_array_equal(np.asarray(leaf), np.asarray(old))
            continue
        b, s = ax.index("batch"), ax.index("kv_seq")
        leaf = np.moveaxis(np.asarray(leaf), (b, s), (0, 1))
        old = np.moveaxis(np.asarray(old), (b, s), (0, 1))
        for slot, p in enumerate(pos):
            np.testing.assert_array_equal(leaf[slot, :p], old[slot, :p])
            assert (leaf[slot, p:] == 0).all()


def test_partial_rejection_rewinds_kv(dense):
    """After a spec run with partial rejections, each live slot's KV
    matches a plain decode's KV on the committed prefix and is zero
    past it (the draft scratch rows really were rewound)."""
    cfg, params = dense
    eng = _engine(cfg, params)
    prompt = np.asarray(
        jax.random.randint(jax.random.fold_in(KEY, 61), (6,), 0,
                           cfg.vocab_size), np.int32)
    sd = SpecDecodeConfig(draft_bits=2, draft_len=3)
    spec_sched = eng.scheduler(num_slots=2, max_len=32, spec_decode=sd)
    plain_sched = eng.scheduler(num_slots=2, max_len=32)
    spec_sched.submit(Request(uid="s", prompt=prompt, max_new_tokens=20))
    plain_sched.submit(Request(uid="p", prompt=prompt, max_new_tokens=20))
    spec_sched.step()                    # admit + first spec round
    spec_sched.step()
    assert spec_sched.metrics.spec_rounds >= 2
    # some rejection must have occurred for the rollback to matter; the
    # int2 slice of a random-init checkpoint disagrees readily
    assert spec_sched.metrics.spec_accepted < spec_sched.metrics.spec_drafted
    pos = int(spec_sched.pos[0])
    plain_sched.step()
    while int(plain_sched.pos[0]) < pos:
        plain_sched.step()
    assert int(plain_sched.pos[0]) == pos        # token-exact => reachable
    axes = jax.tree_util.tree_flatten(
        api.state_axes(cfg), is_leaf=lambda x: isinstance(x, tuple))[0]
    for sl, pl, ax in zip(jax.tree_util.tree_leaves(spec_sched.state),
                          jax.tree_util.tree_leaves(plain_sched.state),
                          axes):
        if "kv_seq" not in ax:
            continue
        b, s = ax.index("batch"), ax.index("kv_seq")
        sl = np.moveaxis(np.asarray(sl), (b, s), (0, 1))
        pl = np.moveaxis(np.asarray(pl), (b, s), (0, 1))
        # committed prefix: verify wrote its own projections, which
        # match plain decode's to fp tolerance (block vs single-step);
        # the spec cache has draft_len extra scratch rows, so compare
        # only the shared prefix
        np.testing.assert_allclose(sl[0, :pos], pl[0, :pos],
                                   rtol=2e-4, atol=2e-4)
        # past the committed prefix: rolled back to zero (the spec
        # cache has draft_len extra scratch rows; all must be clear)
        assert (sl[0, pos:] == 0).all()


# ---------------------------------------------------------------------------
# acceptance bookkeeping
# ---------------------------------------------------------------------------


def test_accept_lengths_oracle():
    draft = np.asarray([[7, 1, 2, 3],     # full agreement -> m = 3
                        [7, 1, 9, 3],     # first mismatch at j=1 -> m = 1
                        [7, 9, 1, 2],     # immediate mismatch -> m = 0
                        [7, 1, 2, 9]])    # late mismatch -> m = 2
    pred = np.asarray([[1, 2, 3, 4]] * 4)
    np.testing.assert_array_equal(accept_lengths(draft, pred), [3, 1, 0, 2])
    # agreement AFTER a mismatch must not resurrect the prefix
    draft2 = np.asarray([[7, 9, 2, 3]])
    np.testing.assert_array_equal(accept_lengths(draft2, pred[:1]), [0])


def test_spec_metrics_bookkeeping(dense):
    """ServeMetrics invariants over a real spec run: every round emits
    accepted + 1 bonus (modulo stop truncation), verify steps stay
    strictly below emitted tokens, and the summary exposes the rates."""
    cfg, params = dense
    eng = _engine(cfg, params)
    prompts = jax.random.randint(jax.random.fold_in(KEY, 71), (3, 6), 0,
                                 cfg.vocab_size)
    eng.generate(prompts, 12,
                 spec_decode=SpecDecodeConfig(draft_bits=4, draft_len=3))
    m = next(iter(eng._schedulers.values())).metrics
    s = m.summary()["spec"]
    assert s["rounds"] == s["verify_steps"] > 0
    assert s["drafted_tokens"] == s["rounds"] * 3
    assert 0.0 <= s["acceptance_rate"] <= 1.0
    # emitted = accepted + one bonus per round, minus stop truncation
    assert s["emitted_tokens"] <= s["accepted_tokens"] + s["rounds"]
    # all requests completed: 12 tokens each, the first from prefill
    # and the remaining 11 from spec rounds
    assert s["emitted_tokens"] == 3 * 11
    assert s["verify_steps"] < s["emitted_tokens"]
    assert s["mean_accepted_prefix_len"] == s["emitted_tokens"] / s["rounds"]
    assert s["verify_steps_per_token"] < 1.0
    # per-slot in-graph acceptance == the NumPy oracle, by construction
    # of the invariants above plus token-exactness (test_spec_decode_*)


# ---------------------------------------------------------------------------
# one compile per (draft, verify) key pair
# ---------------------------------------------------------------------------


def test_one_compile_per_key_pair(dense, monkeypatch):
    cfg, params = dense
    eng = _engine(cfg, params, packed=True, monkeypatch=monkeypatch)
    sd = SpecDecodeConfig(draft_bits=2, draft_len=3)
    prompts = jax.random.randint(jax.random.fold_in(KEY, 81), (2, 6), 0,
                                 cfg.vocab_size)
    eng.generate(prompts, 8, spec_decode=sd)
    eng.generate(prompts, 8, spec_decode=sd)     # revisit: cached closures
    sched = next(iter(eng._schedulers.values()))
    key = ("spec", ("slice", 2), 8)
    # one draft + one verify trace for the pair key, and the plain
    # prefill closure rode along under the verify tier's key
    assert_no_recompiles(sched, require_keys={key, 8})


def test_spec_key_never_collides_with_mixnmatch(dense):
    """A (2, 8) Mix'n'Match bits tuple and the (int2 draft, int8
    verify) pair must key different closures."""
    from repro.serve.specdecode import spec_fns_key
    sd = SpecDecodeConfig(draft_bits=2)
    assert spec_fns_key(sd.draft_key, 8) != (2, 8)
    assert spec_fns_key(sd.draft_key, (2, 8)) != spec_fns_key(sd.draft_key, 8)
    assert sd.draft_key != SpecDecodeConfig(
        draft_bits=2, draft_extra_precision=True).draft_key


def test_spec_config_validation():
    with pytest.raises(ValueError, match="draft_len"):
        SpecDecodeConfig(draft_len=0)
    with pytest.raises(ValueError, match="uniform int"):
        SpecDecodeConfig(draft_bits=(2, 4))
    with pytest.raises(NotImplementedError, match="legacy"):
        cfg = get_config("qwen3_1_7b").reduced()
        params = api.init(KEY, cfg)
        eng = _engine(cfg, params)
        eng.generate(jnp.zeros((1, 4), jnp.int32), 2, extras={"x": 1},
                     spec_decode=SpecDecodeConfig())
