"""Repo tooling: docs guards (check_docs) and the matlint static
analyzer (tools.analysis)."""
