"""matlint: contract-enforcing static analysis for the MatQuant
serving stack.

Four rule families over `src/repro/` (see docs/contracts.md for the
full invariant catalogue):

  R1  jit-site registry        every jax.jit / pl.pallas_call in
                               serve/ + models/ lives in a registered
                               closure cache or the allowlist
  R2  static-metadata hygiene  PackedPlane / SpecDecodeConfig aux
                               fields stay Python scalars; no dict
                               plane access; no Python branches on
                               data leaves in jitted bodies
  R3  donation discipline      donated arguments are never read after
                               the donating call
  R4  host-data contract       jitted closures take host metadata as
                               arguments, never capture it

Run `python -m tools.analysis` (or `make analyze`). Exit codes:
0 = clean, 1 = findings, 2 = usage/parse error. Pure stdlib -- the
pass parses, never imports, so it needs no jax.
"""

from __future__ import annotations

import pathlib

from .base import Finding, Module
from .rules import RULE_IDS, RULES, Context, build_context

__all__ = ["Finding", "Module", "RULES", "RULE_IDS", "Context",
           "analyze_sources", "collect_files", "load_allowlist", "ROOT"]

ROOT = pathlib.Path(__file__).resolve().parent.parent.parent
DEFAULT_ALLOWLIST = pathlib.Path(__file__).resolve().parent / "allowlist.txt"


def load_allowlist(path: pathlib.Path) -> frozenset[str]:
    """Allowlist entries: `RULE path::qualname` per line, `#` comments
    (inline or whole-line) stripped."""
    entries = set()
    for raw in path.read_text().splitlines():
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        parts = line.split(None, 1)
        if len(parts) != 2 or "::" not in parts[1]:
            raise ValueError(
                f"{path}: malformed allowlist line {raw!r} "
                f"(expected `RULE path::qualname`)")
        entries.add(f"{parts[0]} {parts[1]}")
    return frozenset(entries)


def collect_files(paths: list[str]) -> list[pathlib.Path]:
    """Expand CLI path operands (files or directories) to .py files."""
    files: list[pathlib.Path] = []
    for p in paths:
        path = pathlib.Path(p)
        if not path.is_absolute():
            path = ROOT / path
        if path.is_dir():
            files += sorted(path.rglob("*.py"))
        elif path.is_file():
            files.append(path)
        else:
            raise FileNotFoundError(f"no such file or directory: {p}")
    return files


def analyze_sources(sources: list[tuple[str, str]], rules=None,
                    allowlist: frozenset[str] = frozenset()):
    """Run `rules` (default: all) over (rel_path, source) pairs.

    Returns (findings, suppressed): findings whose `allow_key` matches
    an allowlist entry land in `suppressed`. Rule scoping is by the
    rel_path string, so tests can exercise serve/-scoped rules on
    fixture snippets by passing a synthetic path.
    """
    rules = RULES if rules is None else rules
    modules = [Module(rel, src) for rel, src in sources]
    ctx = build_context(modules)
    findings: list[Finding] = []
    suppressed: list[Finding] = []
    for mod in modules:
        for rule in rules:
            for f in rule.check(mod, ctx):
                (suppressed if f.allow_key in allowlist
                 else findings).append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings, suppressed
