"""CLI driver: `python -m tools.analysis [paths...]`.

Exit codes (stable, scripted against by CI and Makefile):
  0  analyzed tree is clean (allowlisted sites report as suppressed)
  1  at least one finding
  2  usage error, missing path, unreadable allowlist, or a file that
     does not parse (syntax errors are analysis failures, not lint
     findings)
"""

from __future__ import annotations

import argparse
import pathlib
import sys

from . import (DEFAULT_ALLOWLIST, ROOT, RULES, analyze_sources,
               collect_files, load_allowlist)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.analysis",
        description="matlint: serving-contract static analysis (R1-R4)")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files/directories to analyze "
                         "(default: src/repro, relative to repo root)")
    ap.add_argument("--allowlist", default=str(DEFAULT_ALLOWLIST),
                    metavar="FILE",
                    help="allowlist file (`RULE path::qualname` lines); "
                         "default: tools/analysis/allowlist.txt")
    ap.add_argument("--rules", default=None, metavar="R1,R2",
                    help="comma-separated subset of rule ids to run")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in RULES:
            print(f"{rule.rule_id}  {rule.title}")
            print(f"    {rule.rationale}")
        return 0

    rules = RULES
    if args.rules:
        wanted = {r.strip() for r in args.rules.split(",") if r.strip()}
        unknown = wanted - {r.rule_id for r in RULES}
        if unknown:
            print(f"matlint: unknown rule id(s): {', '.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2
        rules = tuple(r for r in RULES if r.rule_id in wanted)

    try:
        allow_path = pathlib.Path(args.allowlist)
        if not allow_path.is_absolute():
            allow_path = ROOT / allow_path
        allowlist = load_allowlist(allow_path)
        files = collect_files(args.paths or ["src/repro"])
        sources = []
        for path in files:
            rel = path.relative_to(ROOT).as_posix() \
                if path.is_relative_to(ROOT) else str(path)
            sources.append((rel, path.read_text()))
        findings, suppressed = analyze_sources(sources, rules=rules,
                                               allowlist=allowlist)
    except (OSError, ValueError, SyntaxError) as e:
        print(f"matlint: error: {e}", file=sys.stderr)
        return 2

    for f in findings:
        print(f.format())
    ids = ",".join(r.rule_id for r in rules)
    print(f"matlint: {len(findings)} finding(s) "
          f"({len(suppressed)} allowlisted) across {len(sources)} "
          f"file(s), rules {ids}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
