"""Shared AST plumbing for matlint (tools.analysis).

matlint parses, never imports: every rule runs over `ast` trees, so the
pass needs no jax (the CI `analyze` lane is stdlib-only) and cannot be
confused by import-time side effects. The helpers here give rules the
three things `ast` does not: parent links, enclosing-def qualnames
(the unit of allowlisting), and dotted-name resolution for call sites
like `jax.jit` / `pl.pallas_call` / `functools.partial(jax.jit, ...)`.
"""

from __future__ import annotations

import ast
import dataclasses


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str           # "R1".."R4"
    path: str           # repo-relative posix path
    line: int
    col: int
    message: str
    qualname: str = "<module>"   # enclosing def -- the allowlist unit

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: " \
               f"{self.message}"

    @property
    def allow_key(self) -> str:
        """`R1 src/repro/serve/engine.py::Engine.__init__` -- the exact
        line an operator adds to the allowlist to accept this site."""
        return f"{self.rule} {self.path}::{self.qualname}"


class Module:
    """One parsed file: tree + parent links + qualname resolution."""

    def __init__(self, rel: str, source: str):
        self.rel = rel
        self.tree = ast.parse(source, filename=rel)
        self._parents: dict[ast.AST, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self._parents[child] = node

    def parent(self, node: ast.AST) -> ast.AST | None:
        return self._parents.get(node)

    def ancestors(self, node: ast.AST):
        while node in self._parents:
            node = self._parents[node]
            yield node

    def enclosing_defs(self, node: ast.AST) -> list[ast.FunctionDef]:
        """FunctionDef ancestors, innermost first."""
        return [a for a in self.ancestors(node)
                if isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef))]

    def qualname(self, node: ast.AST) -> str:
        parts = []
        for a in self.ancestors(node):
            if isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef)):
                parts.append(a.name)
        return ".".join(reversed(parts)) or "<module>"

    def enclosing_stmt(self, node: ast.AST) -> ast.stmt | None:
        """The statement containing `node` (node itself if a stmt)."""
        while node is not None and not isinstance(node, ast.stmt):
            node = self._parents.get(node)
        return node

    def module_names(self) -> set[str]:
        """Names bound at module level (imports, defs, assigns) --
        closure free-variable analysis treats these as static."""
        names: set[str] = set()
        for stmt in self.tree.body:
            if isinstance(stmt, ast.Import):
                names |= {(a.asname or a.name).split(".")[0]
                          for a in stmt.names}
            elif isinstance(stmt, ast.ImportFrom):
                names |= {a.asname or a.name for a in stmt.names}
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef)):
                names.add(stmt.name)
            elif isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                targets = (stmt.targets if isinstance(stmt, ast.Assign)
                           else [stmt.target])
                for t in targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            names.add(n.id)
        return names


def dotted_name(expr: ast.AST) -> str | None:
    """`jax.jit` for an Attribute chain over a Name; None otherwise."""
    parts = []
    while isinstance(expr, ast.Attribute):
        parts.append(expr.attr)
        expr = expr.value
    if isinstance(expr, ast.Name):
        parts.append(expr.id)
        return ".".join(reversed(parts))
    return None


JIT_NAMES = {"jax.jit", "jit", "jax.pjit", "pjit"}
PALLAS_SUFFIX = "pallas_call"


def _is_jit_name(name: str | None) -> bool:
    return bool(name) and (name in JIT_NAMES
                           or name == PALLAS_SUFFIX
                           or name.endswith("." + PALLAS_SUFFIX))


def is_jit_call(call: ast.Call) -> bool:
    """True for `jax.jit(...)`, `pl.pallas_call(...)`, and the partial
    spelling `functools.partial(jax.jit, ...)`."""
    name = dotted_name(call.func)
    if _is_jit_name(name):
        return True
    if name in ("functools.partial", "partial") and call.args:
        return _is_jit_name(dotted_name(call.args[0]))
    return False


def is_jit_decorator(dec: ast.AST) -> bool:
    """True for `@jax.jit` / `@jit` and `@partial(jax.jit, ...)`."""
    if isinstance(dec, ast.Call):
        return is_jit_call(dec)
    return _is_jit_name(dotted_name(dec))


def jit_target(call: ast.Call) -> ast.AST | None:
    """The traced callable: first positional arg of the jit call (the
    second for the functools.partial spelling)."""
    args = call.args
    if dotted_name(call.func) in ("functools.partial", "partial"):
        args = args[1:]
    return args[0] if args else None


def const_str(node: ast.AST) -> str | None:
    """The value of a string-constant node (handles the pre-3.9
    `ast.Index` subscript wrapper), else None."""
    if isinstance(node, ast.Index):        # pragma: no cover (py<3.9)
        node = node.value
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None
