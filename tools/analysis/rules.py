"""matlint rules R1-R4: the serving stack's load-bearing contracts.

Each rule class carries `rule_id`, `title`, and `rationale` (surfaced
by `--list-rules` and cross-checked against docs/contracts.md by
tools/check_docs.py) plus `check(module, ctx) -> list[Finding]`.
Allowlist filtering happens centrally in `tools.analysis.run` on
`Finding.allow_key`, so every rule just reports what it sees.
"""

from __future__ import annotations

import ast
import builtins
import dataclasses
import re

from .base import (Finding, Module, const_str, dotted_name, is_jit_call,
                   is_jit_decorator, jit_target)


@dataclasses.dataclass(frozen=True)
class JitInfo:
    """Which of a jitted callable's parameters jit treats as static."""

    static_names: frozenset[str] = frozenset()
    static_nums: frozenset[int] = frozenset()

    def merged(self, other: "JitInfo") -> "JitInfo":
        return JitInfo(self.static_names | other.static_names,
                       self.static_nums | other.static_nums)


def _static_info(call: ast.Call) -> JitInfo:
    """static_argnames/static_argnums declared at a jit call site
    (literal strings/ints only; anything dynamic is ignored)."""
    names: set[str] = set()
    nums: set[int] = set()
    for kw in call.keywords:
        vals = (kw.value.elts
                if isinstance(kw.value, (ast.Tuple, ast.List))
                else [kw.value])
        for v in vals:
            if not isinstance(v, ast.Constant):
                continue
            if kw.arg == "static_argnames" and isinstance(v.value, str):
                names.add(v.value)
            elif kw.arg == "static_argnums" and isinstance(v.value, int):
                nums.add(v.value)
    return JitInfo(frozenset(names), frozenset(nums))


@dataclasses.dataclass
class Context:
    """Cross-file facts shared by all rules for one analysis run.

    R2c needs to know which FunctionDefs are traced by jax.jit. A jit
    target spelled as a bare Name (`jax.jit(prefill)`) or a decorator
    can only refer to a def in the SAME module; an Attribute target
    (`jax.jit(kv_cache.copy_pages, ...)`) may live anywhere, so those
    match by last segment across the file set. Keeping the two maps
    separate stops an inner closure named `prefill` in one module from
    implicating an unrelated top-level `prefill` in another.
    """

    local_jitted: dict[str, dict[str, JitInfo]] = dataclasses.field(
        default_factory=dict)          # module rel -> def name -> info
    attr_jitted: dict[str, JitInfo] = dataclasses.field(
        default_factory=dict)          # last-segment name -> info

    def jit_info(self, mod_rel: str, def_name: str) -> JitInfo | None:
        info = self.local_jitted.get(mod_rel, {}).get(def_name)
        if info is not None:
            return info
        return self.attr_jitted.get(def_name)


def build_context(modules: list[Module]) -> Context:
    ctx = Context()

    def _add(table: dict, key: str, info: JitInfo):
        table[key] = table[key].merged(info) if key in table else info

    for mod in modules:
        local = ctx.local_jitted.setdefault(mod.rel, {})
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and is_jit_call(node):
                target = jit_target(node)
                info = _static_info(node)
                if isinstance(target, ast.Name):
                    _add(local, target.id, info)
                elif isinstance(target, ast.Attribute):
                    name = dotted_name(target)
                    if name:
                        _add(ctx.attr_jitted, name.rsplit(".", 1)[-1], info)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if is_jit_decorator(dec):
                        info = (_static_info(dec)
                                if isinstance(dec, ast.Call) else JitInfo())
                        _add(local, node.name, info)
    return ctx


# -- R1: jit-site registry --------------------------------------------------

class JitSiteRegistry:
    rule_id = "R1"
    title = "jit-site registry"
    rationale = (
        "every jax.jit / pl.pallas_call in src/repro/serve/ and "
        "src/repro/models/ must live inside a registered closure-cache "
        "builder (_step_fns, _paged_step_fns, _spec_fns) or be "
        "explicitly allowlisted -- a stray per-request jit is a "
        "recompile bomb, not a style nit")

    SCOPE = ("src/repro/serve/", "src/repro/models/",
             "src/repro/kernels/paged_attention.py")
    REGISTERED_BUILDERS = frozenset(
        {"_step_fns", "_paged_step_fns", "_spec_fns"})

    def check(self, mod: Module, ctx: Context) -> list[Finding]:
        if not mod.rel.startswith(self.SCOPE):
            return []
        sites: list[ast.AST] = []
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and is_jit_call(node):
                sites.append(node)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                sites += [d for d in node.decorator_list
                          if is_jit_decorator(d)]
        out = []
        for node in sites:
            defs = {d.name for d in mod.enclosing_defs(node)}
            if defs & self.REGISTERED_BUILDERS:
                continue
            qn = mod.qualname(node)
            out.append(Finding(
                self.rule_id, mod.rel, node.lineno, node.col_offset,
                f"jit/pallas_call site outside a registered closure cache "
                f"({', '.join(sorted(self.REGISTERED_BUILDERS))}); route it "
                f"through a keyed cache or add `R1 {mod.rel}::{qn}` to the "
                f"allowlist", qualname=qn))
        return out


# -- R2: static-metadata hygiene --------------------------------------------

class StaticMetadataHygiene:
    rule_id = "R2"
    title = "static-metadata hygiene"
    rationale = (
        "PackedPlane / SpecDecodeConfig aux fields (bits, pack_axis, "
        "extra_precision, slice_bits, slice_ep, draft_*) are pytree "
        "STATIC metadata: assigning them from array-valued expressions "
        "makes the treedef unhashable and every step a retrace; "
        "dict-style plane['words'] access bypasses the static contract "
        "entirely; and a Python if/assert on a data leaf inside a "
        "jitted body is a TracerBoolConversionError at runtime")

    META_FIELDS = frozenset({
        "bits", "pack_axis", "extra_precision", "slice_bits", "slice_ep",
        "draft_bits", "draft_extra_precision", "draft_len"})
    STATIC_CTORS = frozenset({"PackedPlane", "SpecDecodeConfig"})
    PLANE_DATA_KEYS = frozenset({"words", "alpha", "beta", "overflow"})
    ARRAY_BASES = ("jnp.", "jax.numpy.", "np.", "numpy.", "jax.lax.")
    ARRAY_METHODS = frozenset({"astype", "reshape", "sum", "mean", "take"})
    STATIC_ATTRS = frozenset({"shape", "ndim", "dtype", "size"})
    STATIC_CALLS = frozenset({"len", "isinstance", "type"})

    def _array_valued(self, expr: ast.AST) -> bool:
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name and (name.startswith(self.ARRAY_BASES)
                         or name == "jax.device_put"):
                return True
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in self.ARRAY_METHODS):
                return True
        return False

    def check(self, mod: Module, ctx: Context) -> list[Finding]:
        out = []
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                out += self._check_ctor(mod, node)
            elif isinstance(node, ast.Subscript):
                out += self._check_subscript(mod, node)
            elif isinstance(node, ast.Compare):
                out += self._check_membership(mod, node)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                info = ctx.jit_info(mod.rel, node.name)
                if info is not None:
                    out += self._check_jitted_body(mod, node, info)
        return out

    def _check_ctor(self, mod: Module, call: ast.Call) -> list[Finding]:
        name = dotted_name(call.func)
        if name is None:
            return []
        last = name.rsplit(".", 1)[-1]
        if last not in self.STATIC_CTORS and name not in (
                "dataclasses.replace", "replace"):
            return []
        out = []
        for kw in call.keywords:
            if kw.arg in self.META_FIELDS and self._array_valued(kw.value):
                out.append(Finding(
                    self.rule_id, mod.rel, kw.value.lineno,
                    kw.value.col_offset,
                    f"static metadata field `{kw.arg}` assigned from an "
                    f"array-valued expression; aux fields must be Python "
                    f"scalars (call int()/bool() on host, or restructure)",
                    qualname=mod.qualname(call)))
        return out

    def _check_subscript(self, mod: Module,
                         sub: ast.Subscript) -> list[Finding]:
        key = const_str(sub.slice)
        if key not in self.PLANE_DATA_KEYS:
            return []
        return [Finding(
            self.rule_id, mod.rel, sub.lineno, sub.col_offset,
            f"dict-style packed-plane field access [`{key!r}`]; planes are "
            f"core.packing.PackedPlane with static metadata -- use "
            f"attribute access on a real plane, never a legacy dict",
            qualname=mod.qualname(sub))]

    def _check_membership(self, mod: Module,
                          cmp: ast.Compare) -> list[Finding]:
        """`"words" in pw` -- duck-typed detection of a legacy dict
        plane; dead code once every producer builds PackedPlane."""
        if not (isinstance(cmp.left, ast.Constant)
                and cmp.left.value in self.PLANE_DATA_KEYS
                and any(isinstance(op, ast.In) for op in cmp.ops)):
            return []
        return [Finding(
            self.rule_id, mod.rel, cmp.lineno, cmp.col_offset,
            f"dict-style packed-plane detection (`{cmp.left.value!r} in "
            f"...`); planes are core.packing.PackedPlane -- use "
            f"isinstance, never duck-typed dict probing",
            qualname=mod.qualname(cmp))]

    def _check_jitted_body(self, mod: Module, fn: ast.FunctionDef,
                           info: JitInfo) -> list[Finding]:
        args = fn.args
        positional = [a.arg for a in args.posonlyargs + args.args]
        static = set(info.static_names) | {
            positional[i] for i in info.static_nums if i < len(positional)}
        params = ({a.arg for a in (args.posonlyargs + args.args
                                   + args.kwonlyargs)}
                  - {"self"} - static)
        out = []
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)):
                test = node.test
            elif isinstance(node, ast.Assert):
                test = node.test
            else:
                continue
            for name in self._data_leaf_refs(mod, test, params):
                out.append(Finding(
                    self.rule_id, mod.rel, node.lineno, node.col_offset,
                    f"Python {type(node).__name__.lower()} on data leaf "
                    f"`{name}` inside jitted body `{fn.name}`; traced "
                    f"values cannot drive host control flow -- branch on "
                    f"static metadata or use lax.cond/jnp.where",
                    qualname=mod.qualname(node)))
        return out

    def _data_leaf_refs(self, mod: Module, test: ast.AST,
                        params: set[str]) -> list[str]:
        bad = []
        for node in ast.walk(test):
            if not (isinstance(node, ast.Name)
                    and isinstance(node.ctx, ast.Load)
                    and node.id in params):
                continue
            parent = mod.parent(node)
            # static-safe wrappers: x.shape/ndim/dtype, len(x),
            # isinstance(x, ...), and `x is (not) None` structure checks
            if (isinstance(parent, ast.Attribute)
                    and parent.attr in self.STATIC_ATTRS):
                continue
            if (isinstance(parent, ast.Call)
                    and dotted_name(parent.func) in self.STATIC_CALLS):
                continue
            if (isinstance(parent, ast.Compare)
                    and all(isinstance(op, (ast.Is, ast.IsNot))
                            for op in parent.ops)):
                continue
            bad.append(node.id)
        return bad


# -- R3: donation discipline ------------------------------------------------

class DonationDiscipline:
    rule_id = "R3"
    title = "donation discipline"
    rationale = (
        "closures built with donate_argnums invalidate the donated "
        "buffer at the call: any read of that argument after the call "
        "site (without an intervening re-store) is a use-after-donate "
        "-- jax only warns, and the data is garbage")

    def check(self, mod: Module, ctx: Context) -> list[Finding]:
        attr_bindings, name_bindings, dict_keys = self._bindings(mod)
        if not (attr_bindings or name_bindings or dict_keys):
            return []
        out = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            donated = self._donated_for_site(node, attr_bindings,
                                             name_bindings, dict_keys, mod)
            if donated:
                out += self._check_site(mod, node, donated)
        return out

    @staticmethod
    def _donated_idx(call: ast.Call) -> frozenset[int] | None:
        for kw in call.keywords:
            if kw.arg != "donate_argnums":
                continue
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return frozenset({v.value})
            if isinstance(v, (ast.Tuple, ast.List)):
                idx = set()
                for e in v.elts:
                    if not (isinstance(e, ast.Constant)
                            and isinstance(e.value, int)):
                        return None         # non-literal: cannot reason
                    idx.add(e.value)
                return frozenset(idx)
            return None
        return None

    def _bindings(self, mod: Module):
        """Map donating jit closures to the names they are called by:
        `self.X = jax.jit(..)` / `f = jax.jit(..)` direct bindings, and
        dict-literal entries `{"decode": jax.jit(..)}` by string key."""
        attr_bindings: dict[str, frozenset[int]] = {}
        name_bindings: dict[str, frozenset[int]] = {}
        dict_keys: dict[str, frozenset[int]] = {}
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call) and is_jit_call(node)):
                continue
            donated = self._donated_idx(node)
            if not donated:
                continue
            parent = mod.parent(node)
            if (isinstance(parent, ast.Assign) and len(parent.targets) == 1
                    and parent.value is node):
                name = dotted_name(parent.targets[0])
                if name and name.startswith("self."):
                    attr_bindings[name] = donated
                elif name:
                    name_bindings[name] = donated
            elif isinstance(parent, ast.Dict):
                for k, v in zip(parent.keys, parent.values):
                    if v is node and k is not None:
                        key = const_str(k)
                        if key:
                            dict_keys[key] = (dict_keys.get(key, frozenset())
                                              | donated)
        return attr_bindings, name_bindings, dict_keys

    def _donated_for_site(self, call, attr_bindings, name_bindings,
                          dict_keys, mod) -> frozenset[int] | None:
        func = call.func
        name = dotted_name(func)
        if name in attr_bindings:
            return attr_bindings[name]
        if name in name_bindings:
            return name_bindings[name]
        if isinstance(func, ast.Subscript):
            key = const_str(func.slice)
            if key in dict_keys:
                return dict_keys[key]
        # `decode_fn = fns["decode"]; ...; decode_fn(...)` -- resolve the
        # alias within the enclosing function
        if isinstance(func, ast.Name):
            enclosing = mod.enclosing_defs(call)
            scope = enclosing[0] if enclosing else mod.tree
            for stmt in ast.walk(scope):
                if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                        and isinstance(stmt.targets[0], ast.Name)
                        and stmt.targets[0].id == func.id
                        and isinstance(stmt.value, ast.Subscript)):
                    key = const_str(stmt.value.slice)
                    if key in dict_keys:
                        return dict_keys[key]
        return None

    def _check_site(self, mod: Module, call: ast.Call,
                    donated: frozenset[int]) -> list[Finding]:
        out = []
        stmt = mod.enclosing_stmt(call)
        targets: set[str] = set()
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                elts = t.elts if isinstance(t, (ast.Tuple, ast.List)) else [t]
                targets |= {dotted_name(e) for e in elts} - {None}
        for i in sorted(donated):
            if i >= len(call.args) or any(
                    isinstance(a, ast.Starred) for a in call.args[:i + 1]):
                continue                # *args call: cannot resolve arg i
            expr = dotted_name(call.args[i])
            if expr is None:
                continue                # non-trivial expression: skip
            if expr in targets:
                continue                # x = f(x): re-stored immediately
            access = self._first_access_after(mod, stmt, expr)
            if access is not None and isinstance(access, ast.Load):
                out.append(Finding(
                    self.rule_id, mod.rel, call.lineno, call.col_offset,
                    f"`{expr}` is donated (argument {i}) at this call but "
                    f"read again later in the same scope; donated buffers "
                    f"are invalidated -- rebind the result over `{expr}` "
                    f"or drop the donation",
                    qualname=mod.qualname(call)))
        return out

    def _first_access_after(self, mod: Module, stmt: ast.stmt,
                            expr: str) -> ast.expr_context | None:
        """ctx of the first Load/Store of `expr` after `stmt` in the
        enclosing function (lexical line order), or None."""
        enclosing = mod.enclosing_defs(stmt)
        scope = enclosing[0] if enclosing else mod.tree
        first: tuple[int, int, ast.expr_context] | None = None
        for node in ast.walk(scope):
            if isinstance(node, ast.Name):
                name, nctx = node.id, node.ctx
            elif isinstance(node, ast.Attribute):
                name, nctx = dotted_name(node), node.ctx
            else:
                continue
            if name != expr or node.lineno <= (stmt.end_lineno or stmt.lineno):
                continue
            pos = (node.lineno, node.col_offset)
            if first is None or pos < first[:2]:
                first = (*pos, nctx)
        return first[2] if first else None


# -- R4: host-data contract -------------------------------------------------

class HostDataContract:
    rule_id = "R4"
    title = "host-data contract"
    rationale = (
        "page tables, slot positions, and sentinel metadata must flow "
        "into jitted closures as ARGUMENTS (sentinel-padded jnp arrays), "
        "never be captured from enclosing scope -- a captured Python "
        "value bakes one request's host state into the compiled "
        "artifact, so every remap recompiles (or worse, silently "
        "serves a stale table)")

    SCOPE = ("src/repro/serve/",)
    HOST_PAT = re.compile(r"ptab|page|pool|slots|table")
    _BUILTINS = frozenset(dir(builtins))

    def check(self, mod: Module, ctx: Context) -> list[Finding]:
        if not mod.rel.startswith(self.SCOPE):
            return []
        module_names = mod.module_names()
        out = []
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call) and is_jit_call(node)):
                continue
            target = jit_target(node)
            fn = self._resolve_local_def(mod, node, target)
            if fn is None:
                continue
            out += self._check_closure(mod, node, fn, module_names)
        return out

    @staticmethod
    def _resolve_local_def(mod, call, target):
        """The FunctionDef/Lambda being jitted, when it is a closure
        defined in the same enclosing function as the jit call."""
        if isinstance(target, ast.Lambda):
            return target
        if not isinstance(target, ast.Name):
            return None
        for scope in mod.enclosing_defs(call):
            for stmt in ast.walk(scope):
                if (isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))
                        and stmt.name == target.id):
                    return stmt
        return None

    def _check_closure(self, mod, call, fn, module_names) -> list[Finding]:
        args = fn.args
        bound = {a.arg for a in (args.posonlyargs + args.args
                                 + args.kwonlyargs)}
        if args.vararg:
            bound.add(args.vararg.arg)
        if args.kwarg:
            bound.add(args.kwarg.arg)
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        loaded: dict[str, ast.Name] = {}
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, ast.Name):
                    if isinstance(node.ctx, ast.Store):
                        bound.add(node.id)
                    elif node.id not in loaded:
                        loaded[node.id] = node
                elif isinstance(node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    bound.add(node.name)
        name = getattr(fn, "name", "<lambda>")
        out = []
        for var, node in loaded.items():
            if var in bound or var in self._BUILTINS:
                continue
            if var == "self":
                out.append(Finding(
                    self.rule_id, mod.rel, node.lineno, node.col_offset,
                    f"jitted closure `{name}` captures scheduler state via "
                    f"`self`; per-request host data must be passed as an "
                    f"argument so the compiled artifact stays "
                    f"request-independent", qualname=mod.qualname(call)))
            elif var not in module_names and self.HOST_PAT.search(var):
                out.append(Finding(
                    self.rule_id, mod.rel, node.lineno, node.col_offset,
                    f"jitted closure `{name}` captures host-side `{var}` "
                    f"from enclosing scope; pass page tables / slot "
                    f"metadata as (sentinel-padded) array arguments so "
                    f"remaps never recompile", qualname=mod.qualname(call)))
        return out


RULES = (JitSiteRegistry(), StaticMetadataHygiene(), DonationDiscipline(),
         HostDataContract())
RULE_IDS = tuple(r.rule_id for r in RULES)
