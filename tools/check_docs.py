#!/usr/bin/env python
"""Docs fast-lane checks (CI `docs` job + `make lint`).

Two guards, zero dependencies:

1. Markdown link integrity: every relative link target in README.md,
   ROADMAP.md, and docs/*.md must exist on disk (anchors stripped;
   http(s)/mailto links skipped -- CI must not depend on the network).
2. Serve-flag coverage: every `--flag` registered by
   src/repro/launch/serve.py's argparse must appear in docs/serving.md,
   so the operator guide cannot silently drift from the driver.
3. BENCH section coverage: every top-level SECTION (dict-valued key) of
   the committed BENCH_serve.json must appear in docs/serving.md's
   field guide, so a new benchmark section cannot land undocumented.
4. Contract-rule coverage: every matlint rule id (tools.analysis.RULES)
   must have a `## R<n> --` entry in docs/contracts.md, and every rule
   heading there must name a rule the analyzer still implements -- the
   invariant catalogue and the enforcer cannot drift apart.

Exits non-zero listing every failure (not just the first).
"""

from __future__ import annotations

import json
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FLAG_RE = re.compile(r"add_argument\(\s*\"(--[a-z][a-z0-9-]*)\"")


def doc_files() -> list[pathlib.Path]:
    files = [ROOT / "README.md", ROOT / "ROADMAP.md"]
    files += sorted((ROOT / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check_links() -> list[str]:
    errors = []
    for md in doc_files():
        for target in LINK_RE.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path = target.split("#", 1)[0]
            if not path:                    # pure in-page anchor
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
    return errors


def check_serve_flags() -> list[str]:
    serve_py = ROOT / "src" / "repro" / "launch" / "serve.py"
    serving_md = ROOT / "docs" / "serving.md"
    if not serving_md.exists():
        return [f"missing {serving_md.relative_to(ROOT)}"]
    flags = FLAG_RE.findall(serve_py.read_text())
    if not flags:
        return [f"no argparse flags found in {serve_py.relative_to(ROOT)} "
                f"(pattern drift? fix tools/check_docs.py)"]
    doc = serving_md.read_text()
    return [f"docs/serving.md: undocumented launch/serve.py flag {f}"
            for f in flags if f not in doc]


# sections the field guide must document even when the committed
# BENCH_serve.json predates them (e.g. regenerated with a --skip-*
# flag): the dynamic dict-key scan below only sees what was committed
REQUIRED_BENCH_SECTIONS = ("kv_ab", "fleet_ab", "attn_kernel_ab")


def check_bench_sections() -> list[str]:
    bench = ROOT / "BENCH_serve.json"
    serving_md = ROOT / "docs" / "serving.md"
    if not serving_md.exists():
        return []                       # nothing committed to guard yet
    doc = serving_md.read_text()
    errors = [f"docs/serving.md: undocumented BENCH_serve.json section "
              f"`{key}`"
              for key in REQUIRED_BENCH_SECTIONS if f"`{key}`" not in doc]
    if not bench.exists():
        return errors
    try:
        report = json.loads(bench.read_text())
    except json.JSONDecodeError as e:
        return errors + [f"BENCH_serve.json: not valid JSON ({e})"]
    errors += [f"docs/serving.md: undocumented BENCH_serve.json section "
               f"`{key}`"
               for key, val in report.items()
               if isinstance(val, dict) and f"`{key}`" not in doc
               and key not in REQUIRED_BENCH_SECTIONS]
    return errors


RULE_HEADING_RE = re.compile(r"^## (R\d+)\b", re.MULTILINE)


def check_contract_rules() -> list[str]:
    contracts = ROOT / "docs" / "contracts.md"
    if not contracts.exists():
        return ["missing docs/contracts.md (matlint invariant catalogue)"]
    sys.path.insert(0, str(ROOT))
    from tools.analysis import RULE_IDS     # stdlib-only, no jax
    documented = set(RULE_HEADING_RE.findall(contracts.read_text()))
    errors = [f"docs/contracts.md: no `## {rid} --` entry for matlint "
              f"rule {rid}" for rid in RULE_IDS if rid not in documented]
    errors += [f"docs/contracts.md: `## {rid}` documents a rule the "
               f"analyzer does not implement (tools/analysis/rules.py)"
               for rid in sorted(documented - set(RULE_IDS))]
    return errors


def main() -> int:
    errors = (check_links() + check_serve_flags() + check_bench_sections()
              + check_contract_rules())
    for e in errors:
        print(f"docs check FAILED: {e}")
    if not errors:
        n_flags = len(FLAG_RE.findall(
            (ROOT / "src" / "repro" / "launch" / "serve.py").read_text()))
        print(f"docs checks OK: {len(doc_files())} markdown files linked "
              f"cleanly, {n_flags} serve flags documented")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
